//! Virtual-time serving engine: drives the scheduler + fetch engines +
//! MI300X perf model over a synthetic workload, producing the Fig. 16/17
//! measurements.
//!
//! Resource model (per engine replica):
//! - **host**: one scheduler thread; framework overhead + fetch API calls
//!   serialize here (this is what b2b batching relieves).
//! - **gpu**: decode/prefill steps serialize here; kernel-based fetch also
//!   consumes GPU time (the contention DMA offload avoids, §2.4).
//! - **pcie**: DMA fetch wire time serializes here FIFO.
//!
//! Requests need not all be present at t=0: a time-ordered arrival queue
//! ([`VirtualEngine::submit_workload`], fed by
//! [`super::workload::WorkloadSpec::generate`]) is ingested as the
//! virtual clock reaches each event, interleaving arrivals with decode
//! steps — open-loop serving with real queueing behavior. At scale,
//! [`VirtualEngine::submit_workload_stream`] attaches the lazy
//! [`super::workload::WorkloadSpec::stream`] source instead: events are
//! drawn on demand as the clock advances, so the resident arrival set
//! stays O(active sessions) no matter how long the episode runs.

use crate::cluster::topology::NicModel;
use crate::cluster::{hier, ClusterTopology, FaultPlan};
use crate::kvcache::fetch::{run_fetch, FetchImpl, FetchOutcome};
use crate::kvcache::{BlockLayout, MigrateOutcome, MigrateSchedule, Migrator};
use crate::obs::{record, SpanKind, Track};
use crate::sim::{Sim, SimConfig};
use crate::util::stats::{LatHist, Reservoir};

use super::comm::CollectiveComm;
use super::config::{DisaggSpec, ServeConfig};
use super::metrics::{ClassStats, RequestSpan, ServeMetrics, SloTarget};
use super::request::{Request, RequestState};
use super::scheduler::{AdmitAction, Scheduler};
use super::workload::{session_cache_key, ArrivalEvent, ArrivalStream, TenantClass, WorkloadSpec};

/// A request being fetched/prefilled, ready at `ready_ns`.
#[derive(Debug)]
struct Pending {
    req: Request,
    ready_ns: u64,
}

/// A future arrival (time-ordered; `warm` pre-populates the CPU tier at
/// ingest time).
#[derive(Debug)]
struct ArrivalSlot {
    req: Request,
    warm: bool,
}

/// Drain threshold: a node whose NIC runs below half speed degrades the
/// shared collectives more than the capacity its absence costs.
const DRAIN_NIC_BELOW: f64 = 0.5;
/// Drain threshold: a ≥ 1.5× compute straggler slows every lockstep step
/// more than dropping the node would.
const DRAIN_COMPUTE_ABOVE: f64 = 1.5;
/// A queued SLO'd request that has burned this fraction of its TTFT
/// budget puts the class at risk — best-effort arrivals are shed.
const SLO_RISK_FRAC: f64 = 0.5;
/// XOR'd into [`ServeConfig::seed`] for the span reservoir's RNG, so its
/// sampling decisions are decorrelated from the workload/scheduler draws
/// that consume the bare seed.
const SPAN_RESERVOIR_STREAM: u64 = 0x5EA1_ED5A_3B1E_55ED;
/// Bound on the waiting-queue scan of the risk check (O(1) per ingest).
const SLO_RISK_SCAN: usize = 64;

/// Engine-local fault state, materialized once at construction when
/// [`ServeConfig::faults`] is set and derates something. Healthy runs
/// never build one: every fault hook below gates on the `Option`, so the
/// healthy engine stays bit-identical to the pre-fault code
/// (`tests/determinism.rs`).
struct FaultContext {
    plan: FaultPlan,
    /// Nodes kept in the serving world after the drain policy (all true
    /// when draining is off); at least one node always survives.
    keep: Vec<bool>,
    /// Compute-time multiplier every decode/prefill step pays: the worst
    /// straggler among surviving nodes (lockstep TP gates on the slowest
    /// rank) times the capacity lost to draining (`n / active` — the
    /// surviving GPUs shoulder the drained nodes' shards).
    compute_scale: f64,
}

impl FaultContext {
    /// Materialize the plan + drain decision for `cfg`; `None` when the
    /// config is fault-free (including a spec that derates nothing).
    fn build(cfg: &ServeConfig) -> Option<FaultContext> {
        let spec = cfg.faults.as_ref()?;
        // The collective planner clamps worlds to its node limit; the
        // fault plan must describe the same world the comm model prices.
        let n = cfg.num_nodes.clamp(1, hier::MAX_NODES);
        let plan = FaultPlan::generate(spec, n, cfg.seed);
        if plan.is_empty() {
            return None;
        }
        let mut keep = vec![true; n];
        if cfg.degrade.drain {
            for (k, h) in plan.nodes.iter().enumerate() {
                if h.nic_factor < DRAIN_NIC_BELOW || h.compute_factor >= DRAIN_COMPUTE_ABOVE {
                    keep[k] = false;
                }
            }
            if keep.iter().all(|&k| !k) {
                // Never drain the whole fleet: deterministically keep
                // node 0 and serve degraded rather than not at all.
                keep[0] = true;
            }
        }
        let active = keep.iter().filter(|&&k| k).count().max(1);
        let compute_scale = plan.worst_compute_factor(Some(&keep)) * (n as f64 / active as f64);
        Some(FaultContext {
            plan,
            keep,
            compute_scale,
        })
    }

    /// Surviving node count.
    fn active(&self) -> usize {
        self.keep.iter().filter(|&&k| k).count().max(1)
    }

    /// Build the fault-aware collective cost model: collectives execute
    /// on the derated (and, when draining, shrunk) **actual** topology;
    /// the degradation-blind policy (`reselect` off) additionally
    /// installs the healthy topology as the selector's belief.
    fn comm(&self, cfg: &ServeConfig) -> CollectiveComm {
        let n = self.plan.num_nodes();
        if n <= 1 {
            // A single-node world has no NIC leg: flat and free, faulted
            // or not (compute stragglers are charged via `compute_scale`).
            return CollectiveComm::degraded(None, None, None);
        }
        let healthy = ClusterTopology::mi300x(n);
        let keep = cfg.degrade.drain.then_some(self.keep.as_slice());
        let actual = self.plan.derate_cluster(&healthy, keep);
        if actual.num_nodes() <= 1 {
            // Drained down to one node: same flat single-node path.
            return CollectiveComm::degraded(None, None, None);
        }
        let link = self.plan.link_health(keep);
        let belief = (!cfg.degrade.reselect).then_some(healthy);
        CollectiveComm::degraded(Some(actual), belief, link)
    }
}

/// Disaggregated-serving state, built once at construction when
/// [`ServeConfig::disagg`] is set. Colocated runs never build one — every
/// disagg hook below gates on the `Option`, so the colocated engine stays
/// bit-identical to the pre-disagg code (`tests/determinism.rs`).
///
/// Resource model: each prefill node is an independent lane (its own GPU
/// frontier — prefill TP stays node-local, folded into the perf model
/// like a 1-node deployment) with its own NIC send port; admitted misses
/// prefill on the least-loaded lane, then migrate their KV to the decode
/// pool through the lane's port ([`crate::kvcache::migrate`]). The
/// engine's shared `gpu_free` / `comm` become the *decode pool's*
/// resources (`comm` is sized for `decode_nodes`).
struct DisaggContext {
    spec: DisaggSpec,
    /// Per-prefill-lane GPU compute frontier.
    prefill_free: Vec<u64>,
    /// Per-prefill-lane NIC send-port frontier (posts + payloads
    /// serialize per port, as everywhere in the cluster layer).
    nic_free: Vec<u64>,
    /// NIC link between the pools (cluster default: 400 Gb/s RoCE).
    nic: NicModel,
    /// Persistent prefill-side + decode-side DES pair for migration legs.
    migrator: Migrator,
    /// Memoized migration cost per (schedule, block count) — like
    /// `fetch_cache`, the DES outcome depends only on copy counts/sizes.
    mig_cache: std::collections::HashMap<(MigrateSchedule, u64), MigrateOutcome>,
}

impl DisaggContext {
    fn build(cfg: &ServeConfig) -> Option<DisaggContext> {
        let spec = cfg.disagg?;
        Some(DisaggContext {
            spec,
            prefill_free: vec![0; spec.prefill_nodes],
            nic_free: vec![0; spec.prefill_nodes],
            nic: NicModel::default(),
            migrator: Migrator::new(),
            mig_cache: std::collections::HashMap::new(),
        })
    }
}

/// Virtual-time serving engine.
pub struct VirtualEngine {
    pub cfg: ServeConfig,
    pub sched: Scheduler,
    /// Persistent DES used to time DMA fetches (engines/queues carry over).
    fetch_sim: Sim,
    now: u64,
    host_free: u64,
    gpu_free: u64,
    pcie_free: u64,
    /// Future arrivals, time-ordered (front = next).
    arrivals: std::collections::VecDeque<ArrivalSlot>,
    /// Lazy arrival source ([`WorkloadSpec::stream`]); `None` when unused
    /// or exhausted. Merged with `arrivals` inside `ingest_arrivals`.
    stream: Option<ArrivalStream>,
    /// One-slot lookahead into `stream` — the engine must know the next
    /// arrival instant without consuming the event.
    stream_peek: Option<ArrivalSlot>,
    /// Id assigned to the next stream-built request.
    stream_next_id: u64,
    pending: Vec<Pending>,
    running: Vec<Request>,
    pub metrics: ServeMetrics,
    /// Memoized fetch cost per (implementation, copy-count). All blocks
    /// are equal-sized, so the count pins the copy shape — but the cost
    /// is implementation-specific, so [`FetchImpl`] must be in the key or
    /// a config change could replay stale outcomes.
    fetch_cache: std::collections::HashMap<(FetchImpl, usize), FetchOutcome>,
    /// Cluster-aware collective sizing (free on a single node; routed
    /// through `cluster::select_cluster` when `cfg.num_nodes > 1`).
    comm: CollectiveComm,
    /// Fault plan + drain state; `None` on healthy runs (the default) —
    /// no fault hook then touches the serving path.
    faults: Option<FaultContext>,
    /// Disaggregated prefill/decode state; `None` on colocated runs (the
    /// default) — no disagg hook then touches the serving path.
    disagg: Option<DisaggContext>,
    /// Queue-depth timeline decimation state (see `record_queue_depth`).
    queue_tick: u64,
    queue_stride: u64,
}

impl VirtualEngine {
    /// Build an engine for `cfg`.
    pub fn new(cfg: ServeConfig) -> Self {
        let layout = BlockLayout::new(cfg.model, cfg.block_tokens);
        let sched = Scheduler::new(
            layout,
            cfg.gpu_blocks,
            cfg.cpu_blocks,
            super::batcher::BatchPolicy {
                max_batch: cfg.max_batch,
                ..Default::default()
            },
            cfg.hit_rate,
            cfg.seed,
            0,
        );
        let faults = FaultContext::build(&cfg);
        let disagg = DisaggContext::build(&cfg);
        let comm = if let Some(ctx) = &faults {
            // Fault plans describe the full fleet; disaggregation assumes
            // a healthy one (the fault context wins the comm model).
            ctx.comm(&cfg)
        } else if let Some(d) = &cfg.disagg {
            // Per-step TP collectives run inside the decode pool only —
            // prefill lanes are node-local (D == 1 makes decode comm-free).
            let mut decode_cfg = cfg.clone();
            decode_cfg.num_nodes = d.decode_nodes;
            CollectiveComm::new(&decode_cfg)
        } else {
            CollectiveComm::new(&cfg)
        };
        let mut metrics = ServeMetrics::default();
        // Bounded-memory series: exact (bit-identical to the historical
        // unbounded vectors) up to `metrics_sample_cap` samples, sketch /
        // reservoir beyond it.
        let cap = cfg.metrics_sample_cap;
        metrics.ttft_ns = LatHist::with_cap(cap);
        metrics.tpot_ns = LatHist::with_cap(cap);
        metrics.requests = Reservoir::with_cap(cap, cfg.seed ^ SPAN_RESERVOIR_STREAM);
        if let Some(ctx) = &faults {
            metrics.drained_nodes = (ctx.plan.num_nodes() - ctx.active()) as u64;
        }
        VirtualEngine {
            sched,
            fetch_sim: Sim::new(SimConfig::mi300x()),
            now: 0,
            host_free: 0,
            gpu_free: 0,
            pcie_free: 0,
            arrivals: std::collections::VecDeque::new(),
            stream: None,
            stream_peek: None,
            stream_next_id: 0,
            pending: Vec::new(),
            running: Vec::new(),
            metrics,
            fetch_cache: std::collections::HashMap::new(),
            comm,
            faults,
            disagg,
            queue_tick: 0,
            queue_stride: 1,
            cfg,
        }
    }

    /// Initialize per-tenant-class accounting: one [`ClassStats`] slot per
    /// workload class, carrying the class name and SLO into the metrics.
    pub fn configure_classes(&mut self, classes: &[TenantClass]) {
        self.metrics.per_class = classes
            .iter()
            .map(|c| ClassStats::with_cap(c.name.clone(), c.slo, self.cfg.metrics_sample_cap))
            .collect();
    }

    /// Submit a request immediately (optionally pre-warming its KV in the
    /// CPU tier) — the all-at-t=0 path the fixed-set benchmarks use.
    pub fn submit(&mut self, req: Request, warm: bool) {
        self.metrics.submitted += 1;
        if warm {
            self.sched.warm_cpu_cache(&req);
        }
        self.sched.submit(req);
    }

    /// Enqueue a future arrival; the engine ingests it once the virtual
    /// clock reaches `req.arrival_ns`. Arrivals must be enqueued in
    /// time order.
    pub fn enqueue(&mut self, req: Request, warm: bool) {
        if let Some(back) = self.arrivals.back() {
            assert!(
                req.arrival_ns >= back.req.arrival_ns,
                "arrivals must be time-ordered"
            );
        }
        self.arrivals.push_back(ArrivalSlot { req, warm });
    }

    /// Enqueue a generated arrival stream ([`super::workload`]): each
    /// event becomes a request tagged with its tenant class, keyed into
    /// the CPU tier by session so conversation turns share a prefix
    /// entry.
    pub fn submit_workload(&mut self, events: &[ArrivalEvent]) {
        let base = self.metrics.submitted + self.arrivals.len() as u64;
        for (i, e) in events.iter().enumerate() {
            let req = Request::new(
                base + i as u64,
                e.prompt_tokens,
                e.output_tokens,
                e.at_ns,
            )
            .with_class(e.class)
            .with_cache_key(session_cache_key(e.session));
            self.enqueue(req, e.warm);
        }
    }

    /// Attach a lazy arrival source ([`WorkloadSpec::stream`]): events are
    /// drawn on demand as the virtual clock advances, so the resident
    /// arrival set stays O(active sessions) instead of O(total requests).
    /// Feeds the scheduler the same requests, in the same order, as
    /// [`VirtualEngine::submit_workload`] over [`WorkloadSpec::generate`]
    /// (`tests/determinism.rs` pins the two paths field for field).
    pub fn submit_workload_stream(&mut self, spec: &WorkloadSpec) {
        assert!(
            self.stream.is_none() && self.stream_peek.is_none(),
            "one arrival stream per engine"
        );
        self.stream_next_id = self.metrics.submitted + self.arrivals.len() as u64;
        self.stream = Some(spec.stream());
        self.refill_stream_peek();
    }

    /// Pull the next stream event (if any) into the one-slot peek buffer,
    /// materializing it as a request exactly like [`Self::submit_workload`].
    fn refill_stream_peek(&mut self) {
        debug_assert!(self.stream_peek.is_none());
        let Some(stream) = self.stream.as_mut() else {
            return;
        };
        match stream.next() {
            Some(e) => {
                let req = Request::new(
                    self.stream_next_id,
                    e.prompt_tokens,
                    e.output_tokens,
                    e.at_ns,
                )
                .with_class(e.class)
                .with_cache_key(session_cache_key(e.session));
                self.stream_next_id += 1;
                self.stream_peek = Some(ArrivalSlot { req, warm: e.warm });
            }
            None => self.stream = None,
        }
    }

    /// Earliest future arrival instant across both sources (the enqueued
    /// deque and the stream lookahead).
    fn next_arrival_ns(&self) -> Option<u64> {
        let q = self.arrivals.front().map(|s| s.req.arrival_ns);
        let s = self.stream_peek.as_ref().map(|s| s.req.arrival_ns);
        match (q, s) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Move every arrival whose time has come into the scheduler, merging
    /// the time-ordered deque with the lazy stream source (ties go to the
    /// deque; either source may be absent).
    fn ingest_arrivals(&mut self) {
        loop {
            let q = self.arrivals.front().map(|s| s.req.arrival_ns);
            let s = self.stream_peek.as_ref().map(|s| s.req.arrival_ns);
            let from_queue = match (q, s) {
                (Some(a), _) if a <= self.now && s.map_or(true, |b| a <= b) => true,
                (_, Some(b)) if b <= self.now => false,
                _ => break,
            };
            let slot = if from_queue {
                self.arrivals.pop_front().unwrap()
            } else {
                let slot = self.stream_peek.take().unwrap();
                self.refill_stream_peek();
                slot
            };
            self.deliver(slot);
        }
    }

    /// Hand one due arrival to the scheduler. Under fault injection with
    /// the `shed` lever on, best-effort arrivals are refused while queued
    /// SLO'd requests are already burning their TTFT budget — the degraded
    /// fleet's capacity goes to the paying class.
    fn deliver(&mut self, slot: ArrivalSlot) {
        if self.faults.is_some()
            && self.cfg.degrade.shed
            && self.class_slo(slot.req.class).is_none()
            && self.slo_at_risk()
        {
            self.metrics.shed += 1;
            return;
        }
        self.metrics.submitted += 1;
        if slot.warm {
            self.sched.warm_cpu_cache(&slot.req);
        }
        self.sched.submit(slot.req);
    }

    /// The SLO of a request's tenant class (`None` = best-effort, and
    /// always `None` for class-less direct submissions).
    fn class_slo(&self, class: u8) -> Option<SloTarget> {
        self.metrics.per_class.get(class as usize).and_then(|c| c.slo)
    }

    /// Is any queued SLO'd request past [`SLO_RISK_FRAC`] of its TTFT
    /// budget? Scans at most [`SLO_RISK_SCAN`] waiting entries — under
    /// sustained overload the at-risk request is near the queue head.
    fn slo_at_risk(&self) -> bool {
        self.sched.waiting.iter().take(SLO_RISK_SCAN).any(|r| {
            self.class_slo(r.class).is_some_and(|slo| {
                let budget = (slo.ttft_ms * 1e6 * SLO_RISK_FRAC) as u64;
                self.now.saturating_sub(r.arrival_ns) > budget
            })
        })
    }

    /// Evict one running best-effort request when the head of the queue
    /// is an SLO'd request stuck behind a full batch (at most one
    /// eviction per admit round). The victim's GPU blocks are released
    /// and it is resubmitted from scratch — its generated tokens are lost
    /// work, but its first-token instant (already streamed) is kept so
    /// TTFT samples are not double-counted.
    fn preempt_for_slo(&mut self) {
        let head_is_slo = self
            .sched
            .waiting
            .front()
            .is_some_and(|r| self.class_slo(r.class).is_some());
        if !head_is_slo || self.running.len() + self.pending.len() < self.cfg.max_batch {
            return;
        }
        let Some(idx) = self
            .running
            .iter()
            .rposition(|r| self.class_slo(r.class).is_none())
        else {
            return;
        };
        let victim = self.running.swap_remove(idx);
        self.sched.finish(victim.id);
        self.metrics.preemptions += 1;
        let mut fresh = Request::new(
            victim.id,
            victim.prompt_tokens,
            victim.max_new_tokens,
            victim.arrival_ns,
        )
        .with_class(victim.class)
        .with_cache_key(victim.cache_key);
        fresh.first_token_ns = victim.first_token_ns;
        self.sched.submit(fresh);
    }

    /// Apply the fault plan's lockstep compute multiplier (identity on
    /// healthy runs — the branch never perturbs them).
    fn scale_compute(&self, t_ns: u64) -> u64 {
        match &self.faults {
            Some(ctx) if ctx.compute_scale > 1.0 => (t_ns as f64 * ctx.compute_scale) as u64,
            _ => t_ns,
        }
    }

    /// Sample the queue-depth signal (waiting + admitted-but-not-decoding)
    /// into a bounded timeline: when the sample vector reaches
    /// `cfg.queue_sample_cap`, resolution halves (every other sample is
    /// dropped, the sampling stride doubles) — deterministic decimation,
    /// O(cap) memory for arbitrarily long runs. The peak is tracked
    /// exactly, independent of decimation.
    fn record_queue_depth(&mut self) {
        let depth = (self.sched.backlog() + self.pending.len()) as u64;
        self.metrics.queue_peak = self.metrics.queue_peak.max(depth);
        let cap = self.cfg.queue_sample_cap;
        if cap < 2 {
            return;
        }
        let tick = self.queue_tick;
        self.queue_tick += 1;
        if tick % self.queue_stride != 0 {
            return;
        }
        if self.metrics.queue_depth.len() >= cap {
            decimate_in_place(&mut self.metrics.queue_depth);
            self.queue_stride *= 2;
            if tick % self.queue_stride != 0 {
                return;
            }
        }
        self.metrics.queue_depth.push((self.now, depth));
    }

    /// Measure the fetch cost of moving `n` blocks, memoized by
    /// `(FetchImpl, count)` — every block has identical size and engines
    /// are assigned by copy index, so the DES outcome depends only on the
    /// implementation and the count, never on the addresses (see
    /// [`BlockLayout::synth_copies`]). Keying by count alone would replay
    /// stale outcomes if `cfg.fetch` changes mid-engine. Equal-shape
    /// copies are materialized only on a memo miss, where the layout
    /// invariant the memo rests on is asserted.
    fn fetch_cost(&mut self, n: u64) -> FetchOutcome {
        let key = (self.cfg.fetch, n as usize);
        if let Some(o) = self.fetch_cache.get(&key) {
            return *o;
        }
        let copies = self.sched.layout.synth_copies(self.sched.gpu, n);
        assert!(
            copies.iter().all(|c| c.2 == self.sched.layout.block_bytes),
            "fetch memo requires equal-size blocks"
        );
        let out = run_fetch(&mut self.fetch_sim, self.cfg.fetch, &copies);
        self.fetch_cache.insert(key, out);
        out
    }

    /// Disaggregated prefill: run the prompt on the least-loaded prefill
    /// lane (node-local TP — no cross-node collective), then migrate the
    /// request's KV blocks to the decode pool through that lane's NIC
    /// port. Returns the instant the request can join the decode batch.
    ///
    /// With the layer-pipelined schedule the decode side may start step 0
    /// while the tail layers are still in flight: the request is ready at
    /// `max(first_ready, total - step0)` after the migration starts — by
    /// the time step 0's compute reaches layer `l`, chunk `l` has landed.
    /// The blocking schedule has `first_ready == total`, so the same
    /// formula charges it the full transfer — the streamed ready instant
    /// is never later, which is the structural form of the "never slower"
    /// acceptance bound.
    fn disagg_prefill(&mut self, prompt_tokens: u64, t_prefill: u64, emitting: bool) -> u64 {
        let n_blocks = self.sched.layout.blocks_for(prompt_tokens);
        let step0 =
            (self.cfg.perf.decode_step_s(self.cfg.model, 1, prompt_tokens) * 1e9) as u64;
        let host_done = self.host_free;
        let layers = self.cfg.model.layers;
        let fetch = self.cfg.fetch;
        let layout = &self.sched.layout;
        let ctx = self.disagg.as_mut().expect("disagg context");
        let key = (ctx.spec.schedule, n_blocks);
        let out = match ctx.mig_cache.get(&key) {
            Some(o) => *o,
            None => {
                let o = ctx.migrator.cost(
                    layout,
                    layers,
                    fetch,
                    &ctx.nic,
                    n_blocks,
                    ctx.spec.schedule,
                );
                ctx.mig_cache.insert(key, o);
                o
            }
        };
        // Least-loaded lane (ties to the lowest index — deterministic).
        let lane = ctx
            .prefill_free
            .iter()
            .enumerate()
            .min_by_key(|&(_, &f)| f)
            .map(|(i, _)| i)
            .unwrap();
        let start = ctx.prefill_free[lane].max(host_done);
        let prefill_done = start + t_prefill;
        ctx.prefill_free[lane] = prefill_done;
        // The lane's NIC port serializes across this lane's migrations:
        // if the port is still draining an earlier cache, everything past
        // the port-open instant shifts by the wait.
        let open_abs = prefill_done + out.nic_open_ns;
        let delay = ctx.nic_free[lane].saturating_sub(open_abs);
        ctx.nic_free[lane] = prefill_done + delay + out.nic_close_ns;
        let ready_rel = out
            .first_ready_ns
            .max(out.total_ns.saturating_sub(step0));
        let ready = prefill_done + delay + ready_rel;
        self.metrics.gpu_busy_ns += t_prefill;
        self.metrics.migrations += 1;
        self.metrics.migrated_bytes += out.bytes;
        self.metrics.migration_ns += delay + out.total_ns;
        self.metrics.migration_nic_busy_ns += out.nic_busy_ns;
        if emitting {
            let node = lane as u8;
            let nic_s = prefill_done + delay + out.nic_open_ns;
            let nic_e = prefill_done + delay + out.nic_close_ns;
            let mig_end = prefill_done + delay + out.total_ns;
            record::with(|r| {
                // Prefill compute on the lane's node-local track.
                r.span(
                    "prefill".to_string(),
                    SpanKind::Gemm,
                    Track::Cu { node },
                    start,
                    prefill_done,
                );
                // D2H save leg on the lane's DMA track.
                r.span(
                    "kv save d2h".to_string(),
                    SpanKind::Copy,
                    Track::Dma {
                        node,
                        gpu: 0,
                        engine: 0,
                    },
                    prefill_done,
                    prefill_done + out.save_ns,
                );
                // NIC port occupancy — exclusive track, serialized by
                // `nic_free` above.
                r.span(
                    "kv migrate".to_string(),
                    SpanKind::Nic,
                    Track::Nic { node },
                    nic_s,
                    nic_e.max(nic_s),
                );
                // H2D fetch leg on the decode pool's PCIe track
                // (contiguous-tail approximation of the chunked leg).
                r.span(
                    "kv migrate h2d".to_string(),
                    SpanKind::Copy,
                    Track::Pcie,
                    mig_end.saturating_sub(out.fetch_ns),
                    mig_end,
                );
            });
        }
        ready
    }

    /// Run until all submitted requests finish; returns the metrics.
    ///
    /// When an [`crate::obs::record`] recorder is active the whole run is
    /// traced as one episode: framework/API time on the scheduler-host
    /// track, step GEMMs on the GPU track, exposed collective remainders
    /// on the comm track, fetch wire time on the PCIe track, and one span
    /// per finished request — with a measure window over the full wall
    /// time, so the critical-path attribution partitions it exactly.
    pub fn run_to_completion(&mut self) -> &ServeMetrics {
        let emitting = record::active();
        let plan0 = crate::collectives::cache::stats();
        let rounds0 = crate::cluster::rounds_cache_stats();
        let episode = if emitting {
            record::with(|r| r.open_episode("serving"))
        } else {
            None
        };
        loop {
            self.ingest_arrivals();
            self.admit();
            self.absorb_ready();
            self.record_queue_depth();
            if !self.running.is_empty() {
                self.decode_step();
                continue;
            }
            // Nothing decoding: advance the virtual clock to the next
            // event — a fetch/prefill completion, a future arrival, or
            // (admission stalled with nothing in flight) the host catching
            // up — then re-plan.
            let next_arrival = self.next_arrival_ns();
            if let Some(ready) = self.pending.iter().map(|p| p.ready_ns).min() {
                let t = match next_arrival {
                    Some(a) => ready.min(a),
                    None => ready,
                };
                self.now = self.now.max(t);
            } else if self.sched.backlog() == 0 {
                match next_arrival {
                    Some(a) => self.now = self.now.max(a),
                    None => break,
                }
            } else {
                // Backlog but nothing in flight: host-time driven
                // admission gap — but never sleep past the next arrival.
                let mut t = self.host_free.max(self.gpu_free);
                if let Some(a) = next_arrival {
                    t = t.min(a);
                }
                self.now = self.now.max(t);
            }
        }
        self.metrics.wall_ns = self.now;
        self.metrics.host_busy_ns = self.host_free.min(self.now);
        let fs = self.comm.fault_stats();
        self.metrics.retries += fs.retries;
        self.metrics.timeouts += fs.timeouts;
        // Cache counters are process-wide (other threads may bump them
        // concurrently): the deltas are display-only and saturating.
        let plan1 = crate::collectives::cache::stats();
        let rounds1 = crate::cluster::rounds_cache_stats();
        self.metrics.plan_cache = (
            plan1.0.saturating_sub(plan0.0),
            plan1.1.saturating_sub(plan0.1),
        );
        self.metrics.rounds_cache = (
            rounds1.0.saturating_sub(rounds0.0),
            rounds1.1.saturating_sub(rounds0.1),
        );
        if emitting {
            let wall = self.metrics.wall_ns;
            // Fault windows (faulted runs only): one control span per
            // degraded node on its host track, so the trace shows *when*
            // and *where* the fleet was sick next to the serving spans.
            if let Some(ctx) = &self.faults {
                record::with(|r| {
                    for (k, h) in ctx.plan.nodes.iter().enumerate() {
                        if h.is_healthy() {
                            continue;
                        }
                        let (s, e) = h.window_ns.unwrap_or((0, wall));
                        r.span(
                            format!("fault window n{k}"),
                            SpanKind::Control,
                            Track::NodeHost { node: k as u8 },
                            s,
                            e.min(wall).max(s),
                        );
                    }
                });
            }
            record::with(|r| r.measure("serving", 0, wall));
        }
        if matches!(episode, Some((_, true))) {
            record::with(|r| r.close_episode());
        }
        &self.metrics
    }

    /// Admit as many waiting requests as the policy allows, charging host /
    /// pcie / gpu resources per the fetch implementation.
    fn admit(&mut self) {
        let emitting = record::active();
        if self.faults.is_some() && self.cfg.degrade.preempt {
            self.preempt_for_slo();
        }
        let in_flight = self.running.len() + self.pending.len();
        let actions = self.sched.admit_round(in_flight);
        for act in actions {
            // Framework (Python/scheduler) overhead serializes on the host.
            let issue_start = self.host_free.max(self.now);
            self.host_free = issue_start + self.cfg.framework_overhead_ns;
            if emitting {
                let end = self.host_free;
                record::with(|r| {
                    r.span(
                        "framework".to_string(),
                        SpanKind::HostApi,
                        Track::SchedHost,
                        issue_start,
                        end,
                    );
                });
            }
            match act {
                AdmitAction::Fetch { mut req, fetch_blocks } => {
                    self.metrics.cache_hits += 1;
                    self.metrics.fetch_bytes += fetch_blocks * self.sched.layout.block_bytes;
                    let cost = self.fetch_cost(fetch_blocks);
                    // API calls serialize on the host thread.
                    let api_start = self.host_free;
                    let api_end = self.host_free + cost.host_ns;
                    self.host_free = api_end;
                    if emitting {
                        record::with(|r| {
                            r.span(
                                "fetch api".to_string(),
                                SpanKind::HostApi,
                                Track::SchedHost,
                                api_start,
                                api_end,
                            );
                        });
                    }
                    let ready = match self.cfg.fetch {
                        FetchImpl::Kernel => {
                            // CU gather kernel contends with model compute
                            // for CUs and memory bandwidth — partially, not
                            // totally (it can co-schedule with decode CTAs).
                            // The serialized share is the §2.4 contention
                            // DMA offload avoids.
                            const CU_CONTENTION: f64 = 0.55;
                            let serialized =
                                (cost.gpu_cu_ns as f64 * CU_CONTENTION) as u64;
                            let start = self.gpu_free.max(api_end);
                            self.gpu_free = start + serialized;
                            self.metrics.gpu_busy_ns += serialized;
                            if emitting {
                                record::with(|r| {
                                    r.span(
                                        "fetch kernel".to_string(),
                                        SpanKind::Gemm,
                                        Track::Gpu,
                                        start,
                                        start + cost.gpu_cu_ns,
                                    );
                                });
                            }
                            start + cost.gpu_cu_ns
                        }
                        _ => {
                            // DMA wire time occupies the PCIe link (FIFO).
                            let wire = cost.total_ns.saturating_sub(cost.host_ns);
                            let start = self.pcie_free.max(api_end);
                            self.pcie_free = start + wire;
                            if emitting {
                                let end = self.pcie_free;
                                record::with(|r| {
                                    r.span(
                                        "kv fetch".to_string(),
                                        SpanKind::Copy,
                                        Track::Pcie,
                                        start,
                                        end,
                                    );
                                });
                            }
                            self.pcie_free
                        }
                    };
                    req.state = RequestState::Fetching;
                    self.pending.push(Pending { req, ready_ns: ready });
                }
                AdmitAction::Prefill { mut req } => {
                    self.metrics.cache_misses += 1;
                    let t = self.scale_compute(
                        (self.cfg.perf.prefill_s(self.cfg.model, req.prompt_tokens) * 1e9) as u64,
                    );
                    let ready = if self.disagg.is_some() {
                        // Disaggregated: prefill on a dedicated lane, then
                        // migrate the KV cache to the decode pool.
                        self.disagg_prefill(req.prompt_tokens, t, emitting)
                    } else {
                        // Cross-node TP all-reduces of the prompt
                        // activations (0 on a single node — folded into
                        // the perf model); only the part no GEMM window
                        // hides lands on the critical path.
                        let comm = self.comm.step_allreduce_split(
                            self.cfg.model,
                            req.prompt_tokens,
                            t,
                            self.cfg.comm_overlap,
                        );
                        let start = self.gpu_free.max(self.host_free);
                        self.gpu_free = start + t + comm.exposed_ns;
                        self.metrics.gpu_busy_ns += t;
                        self.metrics.comm_ns += comm.total_ns;
                        self.metrics.comm_exposed_ns += comm.exposed_ns;
                        self.metrics.comm_hidden_ns += comm.hidden_ns();
                        if emitting {
                            let exposed = comm.exposed_ns;
                            record::with(|r| {
                                r.span(
                                    "prefill".to_string(),
                                    SpanKind::Gemm,
                                    Track::Gpu,
                                    start,
                                    start + t,
                                );
                                if exposed > 0 {
                                    r.span(
                                        "tp allreduce".to_string(),
                                        SpanKind::ExposedComm,
                                        Track::Comm,
                                        start + t,
                                        start + t + exposed,
                                    );
                                }
                            });
                        }
                        self.gpu_free
                    };
                    req.state = RequestState::Prefilling;
                    self.pending.push(Pending {
                        req,
                        ready_ns: ready,
                    });
                }
            }
        }
    }

    /// Move ready pendings into the decode batch.
    fn absorb_ready(&mut self) {
        let now = self.now;
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].ready_ns <= now {
                let mut p = self.pending.swap_remove(i);
                p.req.state = RequestState::Decoding;
                self.running.push(p.req);
            } else {
                i += 1;
            }
        }
    }

    /// One decode step for the whole running batch.
    fn decode_step(&mut self) {
        let batch = self.running.len() as u64;
        debug_assert!(batch > 0);
        let ctx =
            self.running.iter().map(|r| r.context()).sum::<u64>() / batch;
        let t = self
            .scale_compute((self.cfg.perf.decode_step_s(self.cfg.model, batch, ctx) * 1e9) as u64);
        // Cross-node TP all-reduces of the step's activations, sized
        // through the cluster selector (0 on a single node); the step pays
        // only the exposed remainder after per-layer overlap.
        let comm = self
            .comm
            .step_allreduce_split(self.cfg.model, batch, t, self.cfg.comm_overlap);
        let start = self.gpu_free.max(self.now);
        self.gpu_free = start + t + comm.exposed_ns;
        self.now = self.gpu_free;
        self.metrics.gpu_busy_ns += t;
        self.metrics.comm_ns += comm.total_ns;
        self.metrics.comm_exposed_ns += comm.exposed_ns;
        self.metrics.comm_hidden_ns += comm.hidden_ns();
        let emitting = record::active();
        if emitting {
            let exposed = comm.exposed_ns;
            record::with(|r| {
                r.span(
                    format!("decode b{batch}"),
                    SpanKind::Gemm,
                    Track::Gpu,
                    start,
                    start + t,
                );
                if exposed > 0 {
                    r.span(
                        "tp allreduce".to_string(),
                        SpanKind::ExposedComm,
                        Track::Comm,
                        start + t,
                        start + t + exposed,
                    );
                }
            });
        }
        let now = self.now;
        let mut i = 0;
        while i < self.running.len() {
            let r = &mut self.running[i];
            // Preempted re-runs keep their original first-token instant;
            // gate the TTFT sample on it, not on the token count.
            let had_first = r.first_token_ns.is_some();
            r.on_token(now);
            let done = r.state == RequestState::Finished;
            let ttft = (!had_first).then(|| r.ttft_ns().unwrap() as f64);
            let class = r.class;
            self.metrics.tokens_out += 1;
            if let Some(ttft) = ttft {
                self.metrics.ttft_ns.push(ttft);
                if let Some(cs) = self.metrics.per_class.get_mut(class as usize) {
                    cs.ttft_ns.push(ttft);
                }
            }
            if !done {
                i += 1;
                continue;
            }
            // O(1) removal: swap-remove the finished request; `i` is not
            // advanced, so the swapped-in tail element is processed on the
            // next iteration of this same step.
            let r = self.running.swap_remove(i);
            let span = RequestSpan {
                id: r.id,
                arrival_ns: r.arrival_ns,
                first_token_ns: r.first_token_ns.unwrap(),
                finish_ns: r.finished_ns.unwrap(),
                tokens: r.generated,
                class: r.class,
            };
            if let Some(tpot) = span.tpot_ns() {
                self.metrics.tpot_ns.push(tpot);
            }
            if let Some(cs) = self.metrics.per_class.get_mut(r.class as usize) {
                cs.finished += 1;
                cs.tokens_out += r.generated;
                if let Some(tpot) = span.tpot_ns() {
                    cs.tpot_ns.push(tpot);
                }
                if cs.slo.map_or(true, |slo| slo.met_by(&span)) {
                    cs.slo_met += 1;
                }
            }
            self.metrics.requests.push(span);
            self.sched.finish(r.id);
            self.metrics.finished += 1;
            if emitting {
                record::with(|rec| {
                    rec.span(
                        format!("req{}", span.id),
                        SpanKind::Request,
                        Track::Requests,
                        span.arrival_ns,
                        span.finish_ns,
                    );
                });
            }
        }
    }

    /// Single-request TTFT measurement per the paper's §5.3.2 latency
    /// methodology: KV of the whole prompt resident in CPU memory; measure
    /// fetch + one decode step. Returns (ttft_gpu_ns, ttft_total_ns).
    pub fn measure_ttft(cfg: &ServeConfig, prompt_tokens: u64) -> (u64, u64) {
        let mut eng = VirtualEngine::new(cfg.clone());
        let req = Request::new(0, prompt_tokens, 1, 0);
        eng.submit(req, true);
        let m = eng.run_to_completion().clone();
        assert_eq!(m.finished, 1);
        let ttft_total = m.ttft_ns[0] as u64;
        // GPU-side TTFT excludes the framework overhead.
        let ttft_gpu = ttft_total.saturating_sub(cfg.framework_overhead_ns);
        (ttft_gpu, ttft_total)
    }
}

/// Halve a sample timeline in place, keeping every other entry (indices
/// 0, 2, 4, …) — the same survivors as the historical `retain`-toggle
/// scan, via O(len/2) forward index compaction instead of a
/// closure-driven full-vector shift (`decimation_compacts_like_retain`
/// pins the equivalence).
fn decimate_in_place(v: &mut Vec<(u64, u64)>) {
    let keep = v.len().div_ceil(2);
    for i in 1..keep {
        v[i] = v[2 * i];
    }
    v.truncate(keep);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{LLAMA31_8B, QWEN25_0_5B};

    fn run_small(fetch: FetchImpl, n: u64, hit: f64) -> ServeMetrics {
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, fetch);
        cfg.hit_rate = hit;
        cfg.gpu_blocks = 1 << 18;
        let mut eng = VirtualEngine::new(cfg);
        for i in 0..n {
            eng.submit(Request::new(i, 1024, 8, 0), true);
        }
        eng.run_to_completion().clone()
    }

    #[test]
    fn completes_all_requests() {
        let m = run_small(FetchImpl::DmaB2b, 32, 1.0);
        assert_eq!(m.finished, 32);
        assert_eq!(m.tokens_out, 32 * 8);
        assert_eq!(m.cache_hits, 32);
        assert!(m.tps() > 0.0);
        assert_eq!(m.ttft_ns.len(), 32);
        // One span record per finished request; 8 tokens each ⇒ every
        // request contributes a per-token latency sample.
        assert_eq!(m.requests.len(), 32);
        assert_eq!(m.tpot_ns.len(), 32);
        assert!(m
            .requests
            .iter()
            .all(|r| r.finish_ns > r.first_token_ns && r.first_token_ns > r.arrival_ns));
        assert!(m.tpot_pct_ms(99.0) >= m.tpot_pct_ms(50.0));
    }

    #[test]
    fn b2b_beats_baseline_throughput() {
        let base = run_small(FetchImpl::DmaBaseline, 64, 1.0);
        let b2b = run_small(FetchImpl::DmaB2b, 64, 1.0);
        assert!(
            b2b.tps() > 1.2 * base.tps(),
            "b2b {:.0} vs base {:.0} tok/s",
            b2b.tps(),
            base.tps()
        );
    }

    #[test]
    fn misses_prefill_instead_of_fetch() {
        let m = run_small(FetchImpl::DmaB2b, 16, 0.0);
        assert_eq!(m.cache_misses, 16);
        assert_eq!(m.fetch_bytes, 0);
        assert_eq!(m.finished, 16);
    }

    #[test]
    fn ttft_gpu_speedup_band() {
        // Qwen2.5-0.5B @4096, 100% hit: the paper's headline TTFT_GPU
        // speedup is ~2.29×; accept a generous band.
        let base = VirtualEngine::measure_ttft(
            &ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaBaseline),
            4096,
        );
        let b2b = VirtualEngine::measure_ttft(
            &ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b),
            4096,
        );
        let sp_gpu = base.0 as f64 / b2b.0 as f64;
        let sp_total = base.1 as f64 / b2b.1 as f64;
        assert!((1.6..3.2).contains(&sp_gpu), "gpu speedup {sp_gpu}");
        assert!(sp_total < sp_gpu, "framework overhead must dilute: {sp_total}");
        assert!(sp_total > 1.2, "total speedup {sp_total}");
    }

    #[test]
    fn multi_node_charges_hierarchical_collectives() {
        let run_nodes = |nodes: usize, overlap: bool| {
            let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b)
                .with_nodes(nodes)
                .with_comm_overlap(overlap);
            cfg.gpu_blocks = 1 << 18;
            let mut eng = VirtualEngine::new(cfg);
            for i in 0..8 {
                eng.submit(Request::new(i, 1024, 8, 0), true);
            }
            eng.run_to_completion().clone()
        };
        let single = run_nodes(1, true);
        let multi = run_nodes(2, true);
        assert_eq!(single.finished, 8);
        assert_eq!(multi.finished, 8);
        // Single node: TP comm folded into the perf model, nothing here.
        assert_eq!(single.comm_ns, 0);
        assert_eq!(single.comm_exposed_ns + single.comm_hidden_ns, 0);
        // Multi node: the selector-routed all-reduce still shows up on the
        // critical path (the step's final all-reduce can never hide) and
        // slows the run down.
        assert!(multi.comm_ns > 0);
        assert!(multi.comm_exposed_ns > 0);
        assert!(multi.wall_ns > single.wall_ns);
    }

    /// Acceptance (PR 4): the exposed/hidden decomposition is exact, some
    /// comm is genuinely hidden behind compute on a multi-node config, and
    /// hiding it makes every serving number better than the serialized
    /// accounting at identical total collective work.
    #[test]
    fn overlap_hides_comm_and_improves_serving() {
        let run = |overlap: bool| {
            let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b)
                .with_nodes(2)
                .with_comm_overlap(overlap);
            cfg.gpu_blocks = 1 << 18;
            let mut eng = VirtualEngine::new(cfg);
            for i in 0..16 {
                eng.submit(Request::new(i, 1024, 8, 0), true);
            }
            eng.run_to_completion().clone()
        };
        let serial = run(false);
        let fused = run(true);
        for m in [&serial, &fused] {
            assert_eq!(m.finished, 16);
            assert_eq!(m.comm_exposed_ns + m.comm_hidden_ns, m.comm_ns);
        }
        // Serialized engine hides nothing.
        assert_eq!(serial.comm_hidden_ns, 0);
        assert_eq!(serial.comm_exposed_ns, serial.comm_ns);
        // Overlap: exposed < total, and the identical workload finishes
        // sooner / streams faster.
        assert!(fused.comm_hidden_ns > 0);
        assert!(fused.comm_exposed_ns < fused.comm_ns);
        // (Totals are not compared exactly: faster steps can repack later
        // decode batches, shifting per-step collective sizes.)
        assert!(fused.comm_ns > 0);
        assert!(fused.wall_ns < serial.wall_ns);
        assert!(fused.tps() > serial.tps());
        assert!(fused.comm_hidden_frac() > 0.0);
    }

    /// Event-driven arrivals: a request enqueued for a future instant is
    /// invisible until the virtual clock reaches it — the engine idles
    /// across the gap and measures TTFT from the arrival, not from t=0.
    #[test]
    fn arrivals_respect_the_virtual_clock() {
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
        cfg.gpu_blocks = 1 << 18;
        let mut eng = VirtualEngine::new(cfg);
        let gap_ns = 10_000_000_000; // 10 virtual seconds
        eng.enqueue(Request::new(0, 1024, 8, 0), true);
        eng.enqueue(Request::new(1, 1024, 8, gap_ns), true);
        let m = eng.run_to_completion().clone();
        assert_eq!(m.submitted, 2);
        assert_eq!(m.finished, 2);
        assert!(m.wall_ns > gap_ns, "wall must cover the idle gap");
        let late = m.requests.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(late.arrival_ns, gap_ns);
        assert!(late.first_token_ns > gap_ns);
        // Both requests saw an idle engine: TTFTs are measured from their
        // own arrivals and stay far below the gap.
        assert!(m.ttft_ns.iter().all(|&t| t < 1e9));
    }

    /// Workload-driven runs populate the per-class breakdowns, the SLO
    /// attainment counters and the bounded queue-depth timeline.
    #[test]
    fn workload_run_tracks_classes_slo_and_queue() {
        use crate::coordinator::workload::{drive, WorkloadSpec};
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
        cfg.gpu_blocks = 1 << 18;
        let spec = WorkloadSpec::poisson(400.0, 96, 11);
        let m = drive(&cfg, &spec);
        assert_eq!(m.submitted, 96);
        assert_eq!(m.finished, 96);
        assert_eq!(m.per_class.len(), 2);
        let by_class: u64 = m.per_class.iter().map(|c| c.finished).sum();
        assert_eq!(by_class, 96);
        // "chat" carries an SLO; "bulk" is best-effort — every finished
        // request counts as met.
        assert!(m.per_class[0].slo.is_some());
        assert!(m.per_class[1].slo.is_none());
        assert_eq!(m.per_class[1].slo_met, m.per_class[1].finished);
        assert!((0.0..=1.0).contains(&m.slo_attainment()));
        assert!(m.goodput_rps() > 0.0);
        assert!(!m.queue_depth.is_empty());
        // Bounded by the decimation cap (ServeConfig::queue_sample_cap).
        assert!(m.queue_depth.len() <= 2048);
        let sampled_max = m.queue_depth.iter().map(|&(_, d)| d).max().unwrap();
        assert!(m.queue_peak >= sampled_max);
        assert!(m.requests.iter().any(|r| r.class == 1));
    }

    /// An impossible SLO scores zero attainment for its class while the
    /// best-effort class stays at 100% — per-class gating is real.
    #[test]
    fn impossible_slo_scores_zero() {
        use crate::coordinator::metrics::SloTarget;
        use crate::coordinator::workload::{drive, LenDist, TenantClass, WorkloadSpec};
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
        cfg.gpu_blocks = 1 << 18;
        let mut strict =
            TenantClass::simple("strict", 0.5, LenDist::Fixed(512), LenDist::Fixed(8));
        // TTFT can never beat the 1.8ms framework overhead alone.
        strict.slo = Some(SloTarget {
            ttft_ms: 0.0001,
            tpot_ms: 1000.0,
        });
        let easy = TenantClass::simple("easy", 0.5, LenDist::Fixed(512), LenDist::Fixed(8));
        let spec = WorkloadSpec {
            process: crate::coordinator::workload::ArrivalProcess::Poisson { rate_rps: 200.0 },
            classes: vec![strict, easy],
            requests: 32,
            seed: 5,
        };
        let m = drive(&cfg, &spec);
        assert_eq!(m.finished, 32);
        assert_eq!(m.per_class[0].slo_met, 0);
        assert!((m.per_class[0].attainment() - 0.0).abs() < 1e-12);
        assert!((m.per_class[1].attainment() - 1.0).abs() < 1e-12);
        let expect =
            m.per_class[1].finished as f64 / m.finished as f64;
        assert!((m.slo_attainment() - expect).abs() < 1e-12);
    }

    /// A fault spec that derates nothing builds no fault context: the run
    /// replays the no-faults run bit for bit (the zero-perturbation
    /// contract of the whole subsystem).
    #[test]
    fn healthy_fault_spec_is_bit_identical_to_no_faults() {
        use crate::cluster::FaultSpec;
        let base = run_small(FetchImpl::DmaB2b, 16, 1.0);
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
        cfg.hit_rate = 1.0;
        cfg.gpu_blocks = 1 << 18;
        cfg.faults = Some(FaultSpec::default());
        let mut eng = VirtualEngine::new(cfg);
        for i in 0..16 {
            eng.submit(Request::new(i, 1024, 8, 0), true);
        }
        let m = eng.run_to_completion().clone();
        assert_eq!(m.wall_ns, base.wall_ns);
        assert_eq!(m.ttft_ns, base.ttft_ns);
        assert_eq!(m.tpot_ns, base.tpot_ns);
        assert_eq!((m.retries, m.timeouts), (0, 0));
        assert_eq!((m.shed, m.preemptions, m.drained_nodes), (0, 0, 0));
    }

    /// A compute straggler gates every lockstep step: the identical
    /// workload takes strictly longer than on the healthy fleet.
    #[test]
    fn straggler_slows_every_step() {
        use crate::cluster::FaultSpec;
        let base = run_small(FetchImpl::DmaB2b, 8, 1.0);
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
        cfg.hit_rate = 1.0;
        cfg.gpu_blocks = 1 << 18;
        cfg.faults = Some(FaultSpec::parse("straggler=1:1.4").unwrap());
        let mut eng = VirtualEngine::new(cfg);
        for i in 0..8 {
            eng.submit(Request::new(i, 1024, 8, 0), true);
        }
        let m = eng.run_to_completion().clone();
        assert!(
            m.wall_ns > base.wall_ns,
            "straggled {} vs healthy {}",
            m.wall_ns,
            base.wall_ns
        );
        assert_eq!(m.finished, 8);
    }

    /// The drain lever: a badly derated NIC node is evicted from the
    /// serving world (here 2 → 1 nodes, so collectives go flat) while the
    /// blind policy keeps the full world and pays derated collectives.
    #[test]
    fn drain_shrinks_the_world_and_blind_does_not() {
        use crate::cluster::FaultSpec;
        use crate::coordinator::config::DegradePolicy;
        let run = |policy: DegradePolicy| {
            let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b).with_nodes(2);
            cfg.gpu_blocks = 1 << 18;
            cfg.faults = Some(FaultSpec::parse("nic=1:0.1").unwrap());
            cfg.degrade = policy;
            let mut eng = VirtualEngine::new(cfg);
            for i in 0..8 {
                eng.submit(Request::new(i, 1024, 8, 0), true);
            }
            eng.run_to_completion().clone()
        };
        let aware = run(DegradePolicy::aware());
        let blind = run(DegradePolicy::blind());
        assert_eq!(aware.drained_nodes, 1);
        assert_eq!(aware.comm_ns, 0, "a drained-to-one world has no NIC leg");
        assert_eq!(blind.drained_nodes, 0);
        assert!(blind.comm_ns > 0, "blind still pays the derated collectives");
        assert_eq!(aware.finished, 8);
        assert_eq!(blind.finished, 8);
    }

    /// The preempt lever: a queued SLO'd request stuck behind a full
    /// batch evicts a running best-effort request and finishes; the
    /// victim is re-run and finishes too.
    #[test]
    fn preempts_best_effort_for_slo_head() {
        use crate::cluster::FaultSpec;
        use crate::coordinator::workload::{LenDist, TenantClass};
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
        cfg.gpu_blocks = 1 << 18;
        cfg.max_batch = 1;
        cfg.faults = Some(FaultSpec::parse("straggler=1:1.2").unwrap());
        let mut eng = VirtualEngine::new(cfg);
        let mut chat = TenantClass::simple("chat", 0.5, LenDist::Fixed(64), LenDist::Fixed(8));
        chat.slo = Some(SloTarget {
            ttft_ms: 50.0,
            tpot_ms: 50.0,
        });
        let bulk = TenantClass::simple("bulk", 0.5, LenDist::Fixed(64), LenDist::Fixed(256));
        eng.configure_classes(&[chat, bulk]);
        eng.enqueue(Request::new(0, 64, 256, 0).with_class(1), true);
        eng.enqueue(Request::new(1, 64, 8, 1_000_000).with_class(0), true);
        let m = eng.run_to_completion().clone();
        assert!(m.preemptions >= 1, "the best-effort run must be evicted");
        assert_eq!(m.finished, 2, "the victim is re-run to completion");
        assert_eq!(m.ttft_ns.len(), 2, "one TTFT sample per request, not per run");
        assert_eq!(m.shed, 0);
    }

    /// The shed lever: once a queued SLO'd request has burned half its
    /// TTFT budget, an incoming best-effort arrival is refused.
    #[test]
    fn sheds_best_effort_under_slo_risk() {
        use crate::cluster::FaultSpec;
        use crate::coordinator::config::DegradePolicy;
        use crate::coordinator::workload::{LenDist, TenantClass};
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
        cfg.gpu_blocks = 1 << 18;
        cfg.max_batch = 1;
        cfg.faults = Some(FaultSpec::parse("straggler=1:1.2").unwrap());
        cfg.degrade = DegradePolicy {
            reselect: false,
            drain: false,
            shed: true,
            preempt: false,
        };
        let mut eng = VirtualEngine::new(cfg);
        let mut chat = TenantClass::simple("chat", 0.5, LenDist::Fixed(64), LenDist::Fixed(8));
        chat.slo = Some(SloTarget {
            ttft_ms: 2.0,
            tpot_ms: 50.0,
        });
        let bulk = TenantClass::simple("bulk", 0.5, LenDist::Fixed(64), LenDist::Fixed(512));
        eng.configure_classes(&[chat, bulk]);
        // Best-effort occupies the single batch slot; the SLO'd request
        // queues behind it; a later best-effort arrival lands after the
        // SLO'd wait exceeds half the 2 ms TTFT budget and is shed.
        eng.enqueue(Request::new(0, 64, 512, 0).with_class(1), true);
        eng.enqueue(Request::new(1, 64, 8, 100_000).with_class(0), true);
        eng.enqueue(Request::new(2, 64, 512, 3_000_000).with_class(1), true);
        let m = eng.run_to_completion().clone();
        assert_eq!(m.shed, 1, "the late best-effort arrival must be refused");
        assert_eq!(m.submitted, 2);
        assert_eq!(m.finished, 2);
        assert_eq!(m.preemptions, 0);
    }

    /// The in-place timeline decimation keeps exactly the samples the
    /// historical `retain`-toggle scan kept (indices 0, 2, 4, …), at
    /// every length including the empty and odd cases.
    #[test]
    fn decimation_compacts_like_retain() {
        for len in 0..9u64 {
            let v: Vec<(u64, u64)> = (0..len).map(|i| (i, 100 + i)).collect();
            let mut fast = v.clone();
            decimate_in_place(&mut fast);
            let mut reference = v;
            let mut keep = false;
            reference.retain(|_| {
                keep = !keep;
                keep
            });
            assert_eq!(fast, reference, "len {len}");
        }
    }

    /// Degenerate workloads: a zero-request spec terminates immediately
    /// with empty metrics, and a single-arrival stream yields size-1
    /// series — no panics anywhere in the streaming path.
    #[test]
    fn degenerate_workloads_do_not_panic() {
        use crate::coordinator::workload::{drive, WorkloadSpec};
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
        cfg.gpu_blocks = 1 << 18;
        let empty = drive(&cfg, &WorkloadSpec::poisson(500.0, 0, 7));
        assert_eq!((empty.submitted, empty.finished), (0, 0));
        assert!(empty.ttft_ns.is_empty() && empty.tpot_ns.is_empty());
        assert!(empty.requests.is_empty());
        assert_eq!(empty.wall_ns, 0);
        let one = drive(&cfg, &WorkloadSpec::poisson(500.0, 1, 7));
        assert_eq!(one.finished, 1);
        assert_eq!(one.ttft_ns.len(), 1);
        assert_eq!(one.requests.len(), 1);
        assert!(one.ttft_pct_ms(99.0) > 0.0);
    }

    /// The lazy stream source feeds the engine the exact same requests as
    /// the materialized `generate()` + `submit_workload` path: every
    /// serving metric replays bit for bit.
    #[test]
    fn streaming_drive_matches_materialized_submission() {
        use crate::coordinator::workload::WorkloadSpec;
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
        cfg.gpu_blocks = 1 << 18;
        let spec = WorkloadSpec::poisson(600.0, 64, 17);
        let mut a = VirtualEngine::new(cfg.clone());
        a.configure_classes(&spec.classes);
        a.submit_workload_stream(&spec);
        let ma = a.run_to_completion().clone();
        let mut b = VirtualEngine::new(cfg);
        b.configure_classes(&spec.classes);
        b.submit_workload(&spec.generate());
        let mb = b.run_to_completion().clone();
        assert_eq!(ma.wall_ns, mb.wall_ns);
        assert_eq!(ma.ttft_ns, mb.ttft_ns);
        assert_eq!(ma.tpot_ns, mb.tpot_ns);
        assert_eq!(ma.requests, mb.requests);
        assert_eq!(ma.queue_depth, mb.queue_depth);
        assert_eq!((ma.submitted, ma.finished), (mb.submitted, mb.finished));
        assert_eq!((ma.cache_hits, ma.fetch_bytes), (mb.cache_hits, mb.fetch_bytes));
    }

    /// The fetch-cost memo keys on the implementation, not just the block
    /// count: flipping `cfg.fetch` on a live engine must re-measure, and
    /// flipping back must replay the original memo entry.
    #[test]
    fn fetch_cost_memo_keys_on_impl() {
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaBaseline);
        cfg.gpu_blocks = 1 << 18;
        let mut eng = VirtualEngine::new(cfg);
        let base = eng.fetch_cost(64);
        eng.cfg.fetch = FetchImpl::DmaB2b;
        let b2b = eng.fetch_cost(64);
        assert!(
            base.host_ns > 10 * b2b.host_ns,
            "stale memo: baseline {} vs b2b {} host ns",
            base.host_ns,
            b2b.host_ns
        );
        // Both entries coexist and replay exactly.
        assert_eq!(eng.fetch_cost(64).host_ns, b2b.host_ns);
        eng.cfg.fetch = FetchImpl::DmaBaseline;
        assert_eq!(eng.fetch_cost(64).host_ns, base.host_ns);
    }

    fn disagg_cfg(schedule_blocking: bool) -> ServeConfig {
        use crate::coordinator::config::DisaggSpec;
        let mut spec = DisaggSpec::new(1, 1);
        if schedule_blocking {
            spec = spec.blocking();
        }
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b).with_disagg(spec);
        cfg.gpu_blocks = 1 << 18;
        cfg.hit_rate = 0.0; // every request takes the prefill+migrate path
        cfg
    }

    /// Disaggregated routing: misses prefill on the prefill lane and
    /// migrate their whole KV to the decode pool; a 1-node decode pool
    /// pays no cross-node collective per step.
    #[test]
    fn disagg_routes_prefill_and_migrates() {
        let mut eng = VirtualEngine::new(disagg_cfg(false));
        for i in 0..8 {
            eng.submit(Request::new(i, 4096, 8, 0), false);
        }
        let m = eng.run_to_completion().clone();
        assert_eq!(m.finished, 8);
        assert_eq!(m.cache_misses, 8);
        assert_eq!(m.migrations, 8);
        let layout = BlockLayout::new(&QWEN25_0_5B, 16);
        assert_eq!(
            m.migrated_bytes,
            8 * layout.blocks_for(4096) * layout.block_bytes
        );
        assert!(m.migration_ns > 0);
        assert!(m.migration_nic_busy_ns > 0);
        assert_eq!(m.comm_ns, 0, "1-node decode pool has no NIC collective");
        // Colocated runs never touch the migration path.
        let colo = run_small(FetchImpl::DmaB2b, 8, 0.0);
        assert_eq!((colo.migrations, colo.migrated_bytes), (0, 0));
    }

    /// The serving-level form of the acceptance bound: with everything
    /// else identical, the layer-pipelined migration schedule yields a
    /// TTFT no worse than the blocking bulk transfer — and strictly
    /// better once the prompt is big enough to stream in many chunks.
    #[test]
    fn disagg_pipelined_ttft_beats_blocking() {
        let ttft = |blocking: bool| {
            let mut eng = VirtualEngine::new(disagg_cfg(blocking));
            eng.submit(Request::new(0, 4096, 8, 0), false);
            let m = eng.run_to_completion().clone();
            assert_eq!(m.finished, 1);
            assert_eq!(m.migrations, 1);
            m.ttft_ns[0]
        };
        let blocking = ttft(true);
        let pipelined = ttft(false);
        assert!(
            pipelined < blocking,
            "pipelined {pipelined} !< blocking {blocking}"
        );
        // Small prompts degenerate to a single chunk: never worse.
        let ttft_small = |blocking: bool| {
            let mut eng = VirtualEngine::new(disagg_cfg(blocking));
            eng.submit(Request::new(0, 32, 8, 0), false);
            eng.run_to_completion().ttft_ns[0]
        };
        assert!(ttft_small(false) <= ttft_small(true));
    }

    /// Multiple prefill lanes parallelize prompt processing: a 2:1 split
    /// drains a prefill-heavy burst no slower than 1:1 (same decode pool).
    #[test]
    fn disagg_prefill_lanes_parallelize() {
        use crate::coordinator::config::DisaggSpec;
        let run = |p: usize| {
            let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b)
                .with_disagg(DisaggSpec::new(p, 1));
            cfg.gpu_blocks = 1 << 18;
            cfg.hit_rate = 0.0;
            let mut eng = VirtualEngine::new(cfg);
            for i in 0..8 {
                eng.submit(Request::new(i, 4096, 8, 0), false);
            }
            eng.run_to_completion().clone()
        };
        let one = run(1);
        let two = run(2);
        assert_eq!(one.finished, 8);
        assert_eq!(two.finished, 8);
        assert!(two.wall_ns <= one.wall_ns, "{} > {}", two.wall_ns, one.wall_ns);
    }

    #[test]
    fn big_models_gain_less() {
        let f = |m: &'static crate::models::ModelConfig| {
            let b = VirtualEngine::measure_ttft(&ServeConfig::new(m, FetchImpl::DmaBaseline), 4096);
            let o = VirtualEngine::measure_ttft(&ServeConfig::new(m, FetchImpl::DmaB2b), 4096);
            b.0 as f64 / o.0 as f64
        };
        let small = f(&QWEN25_0_5B);
        let big = f(&LLAMA31_8B);
        assert!(small > big, "small {small} vs big {big}");
        assert!(big >= 0.95, "big model should not regress: {big}");
    }
}
