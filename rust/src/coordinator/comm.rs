//! Cluster-aware collective sizing on the serving path.
//!
//! Tensor-parallel serving spends its communication budget on all-reduce
//! (lowered as reduce-scatter + all-gather). On the paper's single 8-GPU
//! node that cost is folded into the MI300X roofline perf model
//! ([`crate::models::perf`]), so a single-node deployment adds nothing
//! here. When a deployment spans nodes ([`ServeConfig::num_nodes`] > 1),
//! the engine must instead size every step's collective through the
//! cluster-aware selector ([`crate::cluster::select_cluster`] via
//! [`crate::cluster::select_allreduce`]) and charge the hierarchical
//! executor's modeled latency — the flat single-node selector knows nothing
//! about the NIC leg and would undersize it badly.
//!
//! [`CollectiveComm`] memoizes the modeled latency per padded size (the DES
//! outcome is a pure function of the byte count for a fixed cluster), so
//! the serving loop pays one hierarchical episode per distinct batch shape.

use std::collections::HashMap;

use crate::cluster::{
    hier, run_hier_ar, select_allreduce, ClusterChoice, ClusterTopology, HierRunOptions,
};
use crate::models::ModelConfig;

use super::config::ServeConfig;

/// Per-engine collective cost model: flat (free) on one node, hierarchical
/// (selector-routed) across nodes.
pub struct CollectiveComm {
    /// `None` on single-node deployments — the flat path builds no cluster
    /// topology and charges nothing.
    cluster: Option<ClusterTopology>,
    /// Modeled all-reduce latency per padded size.
    cache: HashMap<u64, u64>,
}

impl CollectiveComm {
    /// Build from the serving config ([`ServeConfig::num_nodes`] decides
    /// the path). Node counts above the hierarchical planner's
    /// [`hier::MAX_NODES`] limit are clamped to it — the collective cost is
    /// then modeled for the largest supported cluster (an underestimate),
    /// and a warning records the divergence from the config.
    pub fn new(cfg: &ServeConfig) -> Self {
        if cfg.num_nodes > hier::MAX_NODES {
            crate::log_warn!(
                "num_nodes {} exceeds the cluster planner limit {}; collective \
                 costs are modeled for a {}-node cluster",
                cfg.num_nodes,
                hier::MAX_NODES,
                hier::MAX_NODES
            );
        }
        let cluster = (cfg.num_nodes > 1)
            .then(|| ClusterTopology::mi300x(cfg.num_nodes.min(hier::MAX_NODES)));
        CollectiveComm {
            cluster,
            cache: HashMap::new(),
        }
    }

    /// Whether the hierarchical (multi-node) path is active.
    pub fn is_multi_node(&self) -> bool {
        self.cluster.is_some()
    }

    /// The selector's decision for an all-reduce of `bytes`: the
    /// (reduce-scatter, all-gather) phase choices, or `None` on a
    /// single-node deployment (flat path — no cluster collective is built).
    pub fn allreduce_choices(&self, bytes: u64) -> Option<(ClusterChoice, ClusterChoice)> {
        self.cluster
            .as_ref()
            .map(|cl| select_allreduce(cl, cl.pad_size(bytes)))
    }

    /// Modeled latency of one tensor-parallel all-reduce of `bytes` across
    /// the deployment. 0 on a single node and for zero-byte transfers.
    pub fn allreduce_ns(&mut self, bytes: u64) -> u64 {
        let Some(cl) = &self.cluster else {
            return 0;
        };
        if bytes == 0 {
            return 0;
        }
        let size = cl.pad_size(bytes);
        if let Some(&t) = self.cache.get(&size) {
            return t;
        }
        let (rs, ag) = select_allreduce(cl, size);
        let t = run_hier_ar(rs, ag, cl, size, &HierRunOptions::default()).latency_ns;
        self.cache.insert(size, t);
        t
    }

    /// Collective time for one model step over `tokens` rows: a bf16
    /// activation all-reduce per layer for each of the two TP-sharded
    /// blocks (attention output + MLP output).
    pub fn step_allreduce_ns(&mut self, model: &ModelConfig, tokens: u64) -> u64 {
        if self.cluster.is_none() {
            return 0;
        }
        let bytes = tokens * model.hidden as u64 * 2;
        2 * model.layers as u64 * self.allreduce_ns(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::fetch::FetchImpl;
    use crate::models::zoo::QWEN25_0_5B;

    fn cfg(nodes: usize) -> ServeConfig {
        ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b).with_nodes(nodes)
    }

    #[test]
    fn single_node_is_flat_and_free() {
        let mut comm = CollectiveComm::new(&cfg(1));
        assert!(!comm.is_multi_node());
        assert_eq!(comm.allreduce_choices(1 << 20), None);
        assert_eq!(comm.allreduce_ns(1 << 20), 0);
        assert_eq!(comm.step_allreduce_ns(&QWEN25_0_5B, 64), 0);
    }

    /// The acceptance check: with `num_nodes > 1` the engine's collective
    /// sizing goes through `cluster::select_cluster` (via
    /// `select_allreduce`) and the hierarchical executor — not the flat
    /// single-node selector.
    #[test]
    fn multi_node_routes_through_select_cluster() {
        let mut comm = CollectiveComm::new(&cfg(2));
        assert!(comm.is_multi_node());
        let cl = ClusterTopology::mi300x(2);
        let bytes = 900_001u64; // deliberately unaligned
        let padded = bytes.div_ceil(16).max(1) * 16;
        let want = select_allreduce(&cl, padded);
        assert_eq!(comm.allreduce_choices(bytes), Some(want));
        let t = comm.allreduce_ns(bytes);
        let (rs, ag) = want;
        let reference = run_hier_ar(rs, ag, &cl, padded, &HierRunOptions::default()).latency_ns;
        assert_eq!(t, reference);
        assert!(t > 0);
    }

    #[test]
    fn zero_bytes_cost_nothing_even_multi_node() {
        let mut comm = CollectiveComm::new(&cfg(4));
        assert_eq!(comm.allreduce_ns(0), 0);
        assert_eq!(comm.step_allreduce_ns(&QWEN25_0_5B, 0), 0);
    }

    #[test]
    fn memoizes_per_padded_size() {
        let mut comm = CollectiveComm::new(&cfg(2));
        let a = comm.allreduce_ns(4096);
        let b = comm.allreduce_ns(4096);
        assert_eq!(a, b);
        assert!(a > 0);
        // Sub-chunk sizes share the padded entry.
        assert_eq!(comm.allreduce_ns(4090), a);
        assert_eq!(comm.cache.len(), 1);
    }

    #[test]
    fn step_cost_scales_with_layers_and_tokens() {
        let mut comm = CollectiveComm::new(&cfg(2));
        let one = comm.step_allreduce_ns(&QWEN25_0_5B, 1);
        let many = comm.step_allreduce_ns(&QWEN25_0_5B, 4096);
        assert!(one > 0);
        assert!(many > one);
        assert_eq!(
            one,
            2 * QWEN25_0_5B.layers as u64 * comm.allreduce_ns(QWEN25_0_5B.hidden as u64 * 2)
        );
    }
}
