//! Cluster-aware collective sizing on the serving path.
//!
//! Tensor-parallel serving spends its communication budget on all-reduce
//! (lowered as reduce-scatter + all-gather). On the paper's single 8-GPU
//! node that cost is folded into the MI300X roofline perf model
//! ([`crate::models::perf`]), so a single-node deployment adds nothing
//! here. When a deployment spans nodes ([`ServeConfig::num_nodes`] > 1),
//! the engine must instead size every step's collective through the
//! cluster-aware selector ([`crate::cluster::select_cluster`] via
//! [`crate::cluster::select_allreduce`]) and charge the hierarchical
//! executor's modeled latency — the flat single-node selector knows nothing
//! about the NIC leg and would undersize it badly.
//!
//! [`CollectiveComm`] memoizes the modeled latency per padded size and
//! selected schedule pair (the DES outcome is a pure function of those for
//! a fixed cluster), so the serving loop pays one hierarchical episode per
//! distinct batch shape.
//!
//! **Overlap decomposition (PR 4).** Real tensor-parallel serving does not
//! serialize every all-reduce behind compute: with the collective on DMA
//! engines and the NIC (the paper's offload thesis), chunk `k`'s
//! all-reduce rides behind the producing GEMM's chunk `k+1` — the
//! cluster layer's [`crate::cluster::overlap`] schedule models exactly
//! this fusion inside the collective, and [`CommCost`] models it against
//! the layer's compute: of each per-layer all-reduce, the part that fits
//! under the producing block's GEMM window is **hidden**; the remainder —
//! plus the step's final all-reduce, which has no following compute — is
//! **exposed** and is all the decode/prefill critical path pays.

use std::collections::HashMap;

use crate::cluster::{
    hier, run_hier_ar, select_allreduce, ClusterChoice, ClusterTopology, FaultStats,
    HierRunOptions, InterSchedule, LinkHealth,
};
use crate::models::ModelConfig;

use super::config::ServeConfig;

/// Overlap-decomposed collective cost of one model step: the exposed part
/// is charged on the serving critical path, the hidden part rides behind
/// compute (`total = exposed + hidden` always).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CommCost {
    /// Full modeled collective time (what a no-overlap engine would pay).
    pub total_ns: u64,
    /// Part that no compute window covers — the critical-path charge.
    pub exposed_ns: u64,
}

impl CommCost {
    /// Part hidden behind compute windows.
    pub fn hidden_ns(&self) -> u64 {
        self.total_ns - self.exposed_ns
    }
}

/// Per-engine collective cost model: flat (free) on one node, hierarchical
/// (selector-routed) across nodes.
///
/// Under fault injection ([`CollectiveComm::degraded`]) the model splits
/// into the **actual** cluster — the derated topology every collective
/// really executes on — and an optional **belief** cluster the selector
/// consults: a degradation-aware engine selects against the actual
/// (derated, possibly drain-shrunk) topology, while the degradation-blind
/// baseline keeps selecting against its healthy belief yet still pays the
/// actual cluster's derated latencies. A [`LinkHealth`] flap table routes
/// the executor through its retry-with-backoff path; retry/timeout
/// counters accumulate in [`CollectiveComm::fault_stats`] per *call* (a
/// memoized latency still represents one executed collective that pays
/// its retries each time).
pub struct CollectiveComm {
    /// The topology collectives execute on. `None` on single-node
    /// deployments — the flat path builds no cluster topology and charges
    /// nothing.
    cluster: Option<ClusterTopology>,
    /// The topology the selector consults; `None` ⇒ same as `cluster`
    /// (healthy runs and the degradation-aware policy).
    belief: Option<ClusterTopology>,
    /// Inter-leg flap table (fault injection); `None` on healthy runs —
    /// the executor takes its original code path.
    link_faults: Option<LinkHealth>,
    /// Accumulated retry/timeout counters across all calls.
    stats: FaultStats,
    /// Modeled all-reduce cost per (padded size, phase schedules). The
    /// schedules are part of the key for the same reason the cluster
    /// rounds cache keys on them: an `Overlapped` episode must never be
    /// served a latency modeled for a barriered composition.
    cache: HashMap<(u64, InterSchedule, InterSchedule), (u64, FaultStats)>,
}

impl CollectiveComm {
    /// Build from the serving config ([`ServeConfig::num_nodes`] decides
    /// the path). Node counts above the hierarchical planner's
    /// [`hier::MAX_NODES`] limit are clamped to it — the collective cost is
    /// then modeled for the largest supported cluster (an underestimate),
    /// and a warning records the divergence from the config.
    pub fn new(cfg: &ServeConfig) -> Self {
        if cfg.num_nodes > hier::MAX_NODES {
            crate::log_warn!(
                "num_nodes {} exceeds the cluster planner limit {}; collective \
                 costs are modeled for a {}-node cluster",
                cfg.num_nodes,
                hier::MAX_NODES,
                hier::MAX_NODES
            );
        }
        let cluster = (cfg.num_nodes > 1)
            .then(|| ClusterTopology::mi300x(cfg.num_nodes.min(hier::MAX_NODES)));
        CollectiveComm {
            cluster,
            belief: None,
            link_faults: None,
            stats: FaultStats::default(),
            cache: HashMap::new(),
        }
    }

    /// Build a fault-degraded cost model: collectives execute on `actual`
    /// (the derated, possibly drain-shrunk topology; `None` = flat
    /// single-node path), the selector consults `belief` when given (the
    /// degradation-blind engine passes its healthy topology here), and
    /// `link_faults` routes the inter legs through the retry watchdog.
    /// A 1-node `actual` should be passed as `None` — a drained-to-one
    /// world has no NIC leg and its collectives are free, like any
    /// single-node deployment.
    pub fn degraded(
        actual: Option<ClusterTopology>,
        belief: Option<ClusterTopology>,
        link_faults: Option<LinkHealth>,
    ) -> Self {
        CollectiveComm {
            cluster: actual,
            belief,
            link_faults,
            stats: FaultStats::default(),
            cache: HashMap::new(),
        }
    }

    /// Retry/timeout counters accumulated so far (all zero when healthy).
    pub fn fault_stats(&self) -> FaultStats {
        self.stats
    }

    /// Whether the hierarchical (multi-node) path is active.
    pub fn is_multi_node(&self) -> bool {
        self.cluster.is_some()
    }

    /// The selector's decision for an all-reduce of `bytes`: the
    /// (reduce-scatter, all-gather) phase choices, or `None` on a
    /// single-node deployment (flat path — no cluster collective is built).
    /// Selection consults the belief topology when one is installed
    /// (degradation-blind engines); sizes always pad to the actual world.
    pub fn allreduce_choices(&self, bytes: u64) -> Option<(ClusterChoice, ClusterChoice)> {
        self.cluster.as_ref().map(|cl| {
            let sel = self.belief.as_ref().unwrap_or(cl);
            select_allreduce(sel, cl.pad_size(bytes))
        })
    }

    /// Modeled latency of one tensor-parallel all-reduce of `bytes` across
    /// the deployment (the selector's schedule — chunk-granular overlapped
    /// on multi-node — applied). 0 on a single node and for zero-byte
    /// transfers.
    pub fn allreduce_ns(&mut self, bytes: u64) -> u64 {
        let Some(cl) = &self.cluster else {
            return 0;
        };
        if bytes == 0 {
            return 0;
        }
        let size = cl.pad_size(bytes);
        let sel = self.belief.as_ref().unwrap_or(cl);
        let (rs, ag) = select_allreduce(sel, size);
        let key = (size, rs.inter, ag.inter);
        if let Some(&(t, fs)) = self.cache.get(&key) {
            self.stats.absorb(fs);
            return t;
        }
        let opts = HierRunOptions {
            link_faults: self.link_faults.clone(),
            ..HierRunOptions::default()
        };
        let res = run_hier_ar(rs, ag, cl, size, &opts);
        self.cache.insert(key, (res.latency_ns, res.faults));
        self.stats.absorb(res.faults);
        res.latency_ns
    }

    /// Collective time for one model step over `tokens` rows: a bf16
    /// activation all-reduce per layer for each of the two TP-sharded
    /// blocks (attention output + MLP output).
    pub fn step_allreduce_ns(&mut self, model: &ModelConfig, tokens: u64) -> u64 {
        if self.cluster.is_none() {
            return 0;
        }
        let bytes = tokens * model.hidden as u64 * 2;
        2 * model.layers as u64 * self.allreduce_ns(bytes)
    }

    /// Overlap-decomposed collective cost of one model step whose GPU
    /// compute takes `step_compute_ns`: each of the `2·layers` per-layer
    /// all-reduces can hide under the GEMM window of the block that
    /// produces its input — chunk `k`'s collective rides behind chunk
    /// `k+1`'s compute, so at most `(world−1)/world` of one all-reduce is
    /// hidable (the first chunk has nothing in flight yet) and never more
    /// than the window itself. The step's final all-reduce stays fully
    /// exposed: the sampled token depends on it, there is no following
    /// compute in the step. With `overlap` false (or on a single node /
    /// degenerate inputs) the whole cost is exposed — the pre-PR-4
    /// behavior.
    pub fn step_allreduce_split(
        &mut self,
        model: &ModelConfig,
        tokens: u64,
        step_compute_ns: u64,
        overlap: bool,
    ) -> CommCost {
        let Some(cl) = &self.cluster else {
            return CommCost::default();
        };
        let world = cl.world_size() as u64;
        let bytes = tokens * model.hidden as u64 * 2;
        let per_ar = self.allreduce_ns(bytes);
        let count = 2 * model.layers as u64;
        let total = count * per_ar;
        if total == 0 {
            return CommCost::default();
        }
        if !overlap || count < 2 {
            return CommCost {
                total_ns: total,
                exposed_ns: total,
            };
        }
        // Compute window of the producing block, split evenly across the
        // step's collectives.
        let window = step_compute_ns / count;
        let hidable = per_ar - per_ar / world.max(1);
        let hidden_per_ar = hidable.min(window);
        let hidden = (count - 1) * hidden_per_ar;
        CommCost {
            total_ns: total,
            exposed_ns: total - hidden,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::fetch::FetchImpl;
    use crate::models::zoo::QWEN25_0_5B;

    fn cfg(nodes: usize) -> ServeConfig {
        ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b).with_nodes(nodes)
    }

    #[test]
    fn single_node_is_flat_and_free() {
        let mut comm = CollectiveComm::new(&cfg(1));
        assert!(!comm.is_multi_node());
        assert_eq!(comm.allreduce_choices(1 << 20), None);
        assert_eq!(comm.allreduce_ns(1 << 20), 0);
        assert_eq!(comm.step_allreduce_ns(&QWEN25_0_5B, 64), 0);
    }

    /// The acceptance check: with `num_nodes > 1` the engine's collective
    /// sizing goes through `cluster::select_cluster` (via
    /// `select_allreduce`) and the hierarchical executor — not the flat
    /// single-node selector.
    #[test]
    fn multi_node_routes_through_select_cluster() {
        let mut comm = CollectiveComm::new(&cfg(2));
        assert!(comm.is_multi_node());
        let cl = ClusterTopology::mi300x(2);
        let bytes = 900_001u64; // deliberately unaligned
        let padded = bytes.div_ceil(16).max(1) * 16;
        let want = select_allreduce(&cl, padded);
        assert_eq!(comm.allreduce_choices(bytes), Some(want));
        let t = comm.allreduce_ns(bytes);
        let (rs, ag) = want;
        let reference = run_hier_ar(rs, ag, &cl, padded, &HierRunOptions::default()).latency_ns;
        assert_eq!(t, reference);
        assert!(t > 0);
    }

    #[test]
    fn zero_bytes_cost_nothing_even_multi_node() {
        let mut comm = CollectiveComm::new(&cfg(4));
        assert_eq!(comm.allreduce_ns(0), 0);
        assert_eq!(comm.step_allreduce_ns(&QWEN25_0_5B, 0), 0);
    }

    #[test]
    fn memoizes_per_padded_size() {
        let mut comm = CollectiveComm::new(&cfg(2));
        let a = comm.allreduce_ns(4096);
        let b = comm.allreduce_ns(4096);
        assert_eq!(a, b);
        assert!(a > 0);
        // Sub-chunk sizes share the padded entry.
        assert_eq!(comm.allreduce_ns(4090), a);
        assert_eq!(comm.cache.len(), 1);
    }

    /// The overlap decomposition is exact (`exposed + hidden == total`),
    /// hides something behind a generous compute window, never hides the
    /// step's final all-reduce, and degrades to fully-exposed with
    /// overlap off / zero window / single node.
    #[test]
    fn split_decomposes_and_hides_only_with_overlap() {
        let mut comm = CollectiveComm::new(&cfg(2));
        let total = comm.step_allreduce_ns(&QWEN25_0_5B, 64);
        let compute = 300_000_000u64;
        let split = comm.step_allreduce_split(&QWEN25_0_5B, 64, compute, true);
        assert_eq!(split.total_ns, total);
        assert_eq!(split.exposed_ns + split.hidden_ns(), split.total_ns);
        assert!(split.exposed_ns < split.total_ns, "nothing hidden");
        assert!(
            split.exposed_ns >= total / (2 * QWEN25_0_5B.layers as u64),
            "the final all-reduce has no following compute to hide behind"
        );
        let off = comm.step_allreduce_split(&QWEN25_0_5B, 64, compute, false);
        assert_eq!(off.total_ns, total);
        assert_eq!(off.exposed_ns, off.total_ns);
        let zero = comm.step_allreduce_split(&QWEN25_0_5B, 64, 0, true);
        assert_eq!(zero.exposed_ns, zero.total_ns);
        let mut one = CollectiveComm::new(&cfg(1));
        assert_eq!(
            one.step_allreduce_split(&QWEN25_0_5B, 64, compute, true),
            CommCost::default()
        );
    }

    #[test]
    fn step_cost_scales_with_layers_and_tokens() {
        let mut comm = CollectiveComm::new(&cfg(2));
        let one = comm.step_allreduce_ns(&QWEN25_0_5B, 1);
        let many = comm.step_allreduce_ns(&QWEN25_0_5B, 4096);
        assert!(one > 0);
        assert!(many > one);
        assert_eq!(
            one,
            2 * QWEN25_0_5B.layers as u64 * comm.allreduce_ns(QWEN25_0_5B.hidden as u64 * 2)
        );
    }
}
