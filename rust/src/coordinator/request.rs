//! Request lifecycle.

/// Unique request id.
pub type RequestId = u64;

/// Lifecycle states (vLLM-style).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Received, not yet admitted.
    Queued,
    /// KV being fetched from the CPU tier (cache hit path).
    Fetching,
    /// Prompt being prefilled on the GPU (cache miss path).
    Prefilling,
    /// In the decode batch, generating tokens.
    Decoding,
    /// All tokens generated.
    Finished,
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Prompt length in tokens (synthetic workloads carry lengths only;
    /// the real server carries token ids separately).
    pub prompt_tokens: u64,
    /// Tokens to generate.
    pub max_new_tokens: u64,
    /// Arrival time (ns, virtual or wall).
    pub arrival_ns: u64,
    pub state: RequestState,
    /// Tokens generated so far.
    pub generated: u64,
    /// Time the first token completed (ns).
    pub first_token_ns: Option<u64>,
    /// Time the request finished (ns).
    pub finished_ns: Option<u64>,
    /// Tenant class index into the workload's class table (0 for
    /// single-class workloads; see `coordinator::workload::TenantClass`).
    pub class: u8,
    /// CPU-tier cache key. Defaults to `id`; conversation replays share a
    /// per-session key so follow-up turns hit the prefix stored by earlier
    /// turns (in real vLLM this is the token-prefix hash).
    pub cache_key: u64,
}

impl Request {
    /// New queued request.
    pub fn new(id: RequestId, prompt_tokens: u64, max_new_tokens: u64, arrival_ns: u64) -> Self {
        Request {
            id,
            prompt_tokens,
            max_new_tokens,
            arrival_ns,
            state: RequestState::Queued,
            generated: 0,
            first_token_ns: None,
            finished_ns: None,
            class: 0,
            cache_key: id,
        }
    }

    /// Tag with a tenant class index (builder style).
    pub fn with_class(mut self, class: u8) -> Self {
        self.class = class;
        self
    }

    /// Override the CPU-tier cache key (builder style).
    pub fn with_cache_key(mut self, key: u64) -> Self {
        self.cache_key = key;
        self
    }

    /// Current context length (prompt + generated).
    pub fn context(&self) -> u64 {
        self.prompt_tokens + self.generated
    }

    /// Record one generated token at time `now`.
    pub fn on_token(&mut self, now: u64) {
        self.generated += 1;
        if self.first_token_ns.is_none() {
            self.first_token_ns = Some(now);
        }
        if self.generated >= self.max_new_tokens {
            self.state = RequestState::Finished;
            self.finished_ns = Some(now);
        }
    }

    /// Time-to-first-token, if produced.
    pub fn ttft_ns(&self) -> Option<u64> {
        self.first_token_ns.map(|t| t - self.arrival_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut r = Request::new(1, 4096, 2, 100);
        assert_eq!(r.state, RequestState::Queued);
        assert_eq!(r.class, 0);
        assert_eq!(r.cache_key, 1); // defaults to the request id
        assert_eq!(r.context(), 4096);
        r.on_token(500);
        assert_eq!(r.ttft_ns(), Some(400));
        assert_eq!(r.state, RequestState::Queued); // state managed externally
        assert_eq!(r.context(), 4097);
        r.on_token(900);
        assert_eq!(r.state, RequestState::Finished);
        assert_eq!(r.finished_ns, Some(900));
    }

    #[test]
    fn builders_override_class_and_key() {
        let r = Request::new(9, 128, 4, 0).with_class(2).with_cache_key(77);
        assert_eq!(r.class, 2);
        assert_eq!(r.cache_key, 77);
    }
}
