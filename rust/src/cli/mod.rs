//! Hand-rolled CLI argument parsing (clap is not in the offline vendor
//! set): subcommand + `--flag value` / `--flag` options + positionals.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`; the first non-flag token is the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // `--k=v`, `--k v`, or boolean switch.
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// From the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// String flag with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional flag.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Parsed numeric flag.
    pub fn get_num<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Boolean switch present?
    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("figures --out results/ --sizes 1K,4G --quick pos1");
        assert_eq!(a.command.as_deref(), Some("figures"));
        assert_eq!(a.get("out", "x"), "results/");
        assert_eq!(a.get("sizes", ""), "1K,4G");
        assert!(a.has("quick") || a.get("quick", "") == "pos1");
    }

    #[test]
    fn eq_form_and_numbers() {
        let a = parse("sweep --max=64M --requests 200");
        assert_eq!(a.get("max", ""), "64M");
        assert_eq!(a.get_num::<u64>("requests", 0), 200);
        assert_eq!(a.get_num::<u64>("missing", 7), 7);
    }

    #[test]
    fn trailing_switch() {
        let a = parse("bench --verbose");
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }
}
