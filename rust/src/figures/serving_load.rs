//! Latency-vs-offered-load curves: production-traffic serving under the
//! seeded arrival processes of [`crate::coordinator::workload`].
//!
//! One [`LoadPoint`] per (workload shape, node count, offered rate):
//! aggregate and per-class TTFT/TPOT percentiles, SLO attainment, goodput
//! and queue peak. The `serving_load` bench sweeps these into
//! `BENCH_PR7.json`; the CLI `serve` subcommand renders them as tables
//! and `results/serving_load.csv`.

use crate::coordinator::workload::{drive, ArrivalProcess, TenantClass, WorkloadSpec};
use crate::coordinator::{ServeConfig, ServeMetrics};
use crate::kvcache::fetch::FetchImpl;
use crate::models::ModelConfig;

/// Per-tenant-class slice of one load point.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassPoint {
    pub name: String,
    pub finished: u64,
    pub ttft_p50_ms: f64,
    pub ttft_p95_ms: f64,
    pub ttft_p99_ms: f64,
    pub tpot_p50_ms: f64,
    pub tpot_p99_ms: f64,
    /// SLO attainment (1.0 for best-effort classes; NaN with 0 finishes).
    pub attainment: f64,
}

/// One measured point on the latency-vs-offered-load curve.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadPoint {
    /// Workload shape (`poisson` / `bursty` / `trace`).
    pub workload: String,
    pub nodes: usize,
    /// Offered (average) arrival rate, requests/second.
    pub rate_rps: f64,
    /// Arrival events offered.
    pub offered: u64,
    pub finished: u64,
    pub ttft_p50_ms: f64,
    pub ttft_p95_ms: f64,
    pub ttft_p99_ms: f64,
    pub tpot_p50_ms: f64,
    pub tpot_p99_ms: f64,
    /// Overall SLO attainment fraction.
    pub attainment: f64,
    /// SLO-meeting requests per second.
    pub goodput_rps: f64,
    pub queue_peak: u64,
    /// Virtual wall time of the run (seconds).
    pub wall_s: f64,
    pub classes: Vec<ClassPoint>,
}

/// The standard serving config for load curves: b2b DMA fetch, a KV pool
/// sized for the batch (not the backlog), `nodes` nodes, overlap on/off.
pub fn serve_config(model: &'static ModelConfig, nodes: usize, overlap: bool) -> ServeConfig {
    let mut cfg = ServeConfig::new(model, FetchImpl::DmaB2b)
        .with_nodes(nodes)
        .with_comm_overlap(overlap);
    cfg.gpu_blocks = 1 << 18;
    cfg
}

/// Condense run metrics into a [`LoadPoint`].
pub fn point_from_metrics(
    workload: &str,
    nodes: usize,
    rate_rps: f64,
    offered: u64,
    m: &ServeMetrics,
) -> LoadPoint {
    LoadPoint {
        workload: workload.to_string(),
        nodes,
        rate_rps,
        offered,
        finished: m.finished,
        ttft_p50_ms: m.ttft_p50_ms(),
        ttft_p95_ms: m.ttft_p95_ms(),
        ttft_p99_ms: m.ttft_p99_ms(),
        tpot_p50_ms: m.tpot_pct_ms(50.0),
        tpot_p99_ms: m.tpot_pct_ms(99.0),
        attainment: m.slo_attainment(),
        goodput_rps: m.goodput_rps(),
        queue_peak: m.queue_peak,
        wall_s: m.wall_ns as f64 / 1e9,
        classes: m
            .per_class
            .iter()
            .map(|c| ClassPoint {
                name: c.name.clone(),
                finished: c.finished,
                ttft_p50_ms: c.ttft_pct_ms(50.0),
                ttft_p95_ms: c.ttft_pct_ms(95.0),
                ttft_p99_ms: c.ttft_pct_ms(99.0),
                tpot_p50_ms: c.tpot_pct_ms(50.0),
                tpot_p99_ms: c.tpot_pct_ms(99.0),
                attainment: c.attainment(),
            })
            .collect(),
    }
}

/// Run one workload at one offered rate and measure a [`LoadPoint`].
/// For `trace` workloads the diurnal day is compressed into the run's
/// expected span, so every run sweeps the full profile.
pub fn measure(
    cfg: &ServeConfig,
    classes: &[TenantClass],
    kind: &str,
    rate_rps: f64,
    requests: u64,
    seed: u64,
) -> LoadPoint {
    assert!(rate_rps > 0.0, "offered rate must be positive");
    let horizon_s = requests as f64 / rate_rps;
    let process = ArrivalProcess::for_kind(kind, rate_rps, horizon_s)
        .unwrap_or_else(|| panic!("unknown workload kind: {kind}"));
    let spec = WorkloadSpec {
        process,
        classes: classes.to_vec(),
        requests,
        seed,
    };
    let m = drive(cfg, &spec);
    point_from_metrics(kind, cfg.num_nodes, rate_rps, requests, &m)
}

/// Closed-loop service capacity of `cfg` under this tenant mix
/// (requests/second with every arrival at t≈0 and conversations
/// flattened — no arrival-process slack).
pub fn estimate_capacity_rps(
    cfg: &ServeConfig,
    classes: &[TenantClass],
    requests: u64,
    seed: u64,
) -> f64 {
    let m = drive(cfg, &WorkloadSpec::closed_loop(classes, requests, seed));
    assert!(m.wall_ns > 0 && m.finished > 0);
    m.finished as f64 / (m.wall_ns as f64 / 1e9)
}

/// Sweep offered load over `rates` for one workload shape.
///
/// Load points are independent virtual-time runs (each [`measure`] call is
/// a pure function of its arguments), so the sweep fans them out across
/// `std::thread` workers — one dispenser index per point, results written
/// into per-point slots — and returns them in `rates` order. The output is
/// identical to the serial loop whatever the worker count or completion
/// order (`parallel_sweep_matches_serial` pins this); single-point or
/// single-core sweeps skip thread setup entirely.
pub fn sweep(
    cfg: &ServeConfig,
    classes: &[TenantClass],
    kind: &str,
    rates: &[f64],
    requests: u64,
    seed: u64,
) -> Vec<LoadPoint> {
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(rates.len());
    if workers <= 1 {
        return rates
            .iter()
            .map(|&r| measure(cfg, classes, kind, r, requests, seed))
            .collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<Option<LoadPoint>>> =
        rates.iter().map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let Some(&r) = rates.get(i) else { break };
                let p = measure(cfg, classes, kind, r, requests, seed);
                *slots[i].lock().unwrap() = Some(p);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("every load point measured"))
        .collect()
}

/// Render the aggregate latency-vs-load table.
pub fn render(points: &[LoadPoint]) -> String {
    let mut t = crate::util::table::Table::new(vec![
        "workload",
        "nodes",
        "rate_rps",
        "reqs",
        "ttft_p50_ms",
        "ttft_p95_ms",
        "ttft_p99_ms",
        "tpot_p99_ms",
        "slo%",
        "goodput_rps",
        "queue_peak",
    ]);
    for p in points {
        t.row(vec![
            p.workload.clone(),
            p.nodes.to_string(),
            format!("{:.0}", p.rate_rps),
            p.finished.to_string(),
            format!("{:.1}", p.ttft_p50_ms),
            format!("{:.1}", p.ttft_p95_ms),
            format!("{:.1}", p.ttft_p99_ms),
            format!("{:.2}", p.tpot_p99_ms),
            format!("{:.1}", p.attainment * 100.0),
            format!("{:.0}", p.goodput_rps),
            p.queue_peak.to_string(),
        ]);
    }
    t.render()
}

/// Render the per-class breakdown of every point.
pub fn render_classes(points: &[LoadPoint]) -> String {
    let mut t = crate::util::table::Table::new(vec![
        "workload",
        "rate_rps",
        "class",
        "reqs",
        "ttft_p50_ms",
        "ttft_p95_ms",
        "ttft_p99_ms",
        "tpot_p50_ms",
        "tpot_p99_ms",
        "slo%",
    ]);
    for p in points {
        for c in &p.classes {
            t.row(vec![
                p.workload.clone(),
                format!("{:.0}", p.rate_rps),
                c.name.clone(),
                c.finished.to_string(),
                format!("{:.1}", c.ttft_p50_ms),
                format!("{:.1}", c.ttft_p95_ms),
                format!("{:.1}", c.ttft_p99_ms),
                format!("{:.2}", c.tpot_p50_ms),
                format!("{:.2}", c.tpot_p99_ms),
                format!("{:.1}", c.attainment * 100.0),
            ]);
        }
    }
    t.render()
}

/// CSV of the aggregate curve (one row per point).
pub fn to_csv(points: &[LoadPoint]) -> crate::util::csv::Csv {
    let mut c = crate::util::csv::Csv::new(vec![
        "workload",
        "nodes",
        "rate_rps",
        "offered",
        "finished",
        "ttft_p50_ms",
        "ttft_p95_ms",
        "ttft_p99_ms",
        "tpot_p50_ms",
        "tpot_p99_ms",
        "slo_attainment",
        "goodput_rps",
        "queue_peak",
        "wall_s",
    ]);
    for p in points {
        c.row(vec![
            p.workload.clone(),
            p.nodes.to_string(),
            format!("{:.2}", p.rate_rps),
            p.offered.to_string(),
            p.finished.to_string(),
            format!("{:.3}", p.ttft_p50_ms),
            format!("{:.3}", p.ttft_p95_ms),
            format!("{:.3}", p.ttft_p99_ms),
            format!("{:.4}", p.tpot_p50_ms),
            format!("{:.4}", p.tpot_p99_ms),
            format!("{:.4}", p.attainment),
            format!("{:.2}", p.goodput_rps),
            p.queue_peak.to_string(),
            format!("{:.3}", p.wall_s),
        ]);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::workload::default_tenants;
    use crate::models::zoo::QWEN25_0_5B;

    #[test]
    fn capacity_is_positive_and_saturation_hurts_p99() {
        let cfg = serve_config(&QWEN25_0_5B, 1, true);
        let classes = default_tenants();
        let cap = estimate_capacity_rps(&cfg, &classes, 96, 7);
        assert!(cap > 0.0, "capacity {cap}");
        // Far under capacity vs far over: p99 TTFT must rise sharply.
        let light = measure(&cfg, &classes, "poisson", cap * 0.3, 96, 7);
        let heavy = measure(&cfg, &classes, "poisson", cap * 3.0, 96, 7);
        assert_eq!(light.finished, 96);
        assert_eq!(heavy.finished, 96);
        assert!(
            heavy.ttft_p99_ms > 2.0 * light.ttft_p99_ms,
            "light {:.1}ms vs heavy {:.1}ms",
            light.ttft_p99_ms,
            heavy.ttft_p99_ms
        );
        assert!(light.attainment >= heavy.attainment);
    }

    /// The threaded sweep returns exactly what the serial loop returns, in
    /// `rates` order — parallelism changes wall-clock only, never results.
    #[test]
    fn parallel_sweep_matches_serial() {
        let cfg = serve_config(&QWEN25_0_5B, 1, true);
        let classes = default_tenants();
        let rates = [150.0, 300.0, 450.0, 600.0, 750.0];
        let serial: Vec<LoadPoint> = rates
            .iter()
            .map(|&r| measure(&cfg, &classes, "poisson", r, 48, 9))
            .collect();
        let parallel = sweep(&cfg, &classes, "poisson", &rates, 48, 9);
        assert_eq!(parallel, serial);
        // Slot-indexed writes pin output order to `rates`, not to worker
        // completion order.
        for (p, &r) in parallel.iter().zip(rates.iter()) {
            assert_eq!(p.rate_rps, r);
        }
    }

    #[test]
    fn render_and_csv_cover_every_point() {
        let cfg = serve_config(&QWEN25_0_5B, 1, true);
        let classes = default_tenants();
        let pts = sweep(&cfg, &classes, "bursty", &[200.0, 400.0], 48, 3);
        assert_eq!(pts.len(), 2);
        assert!(pts.iter().all(|p| p.classes.len() == 2));
        let table = render(&pts);
        assert!(table.contains("bursty"));
        let classes_table = render_classes(&pts);
        assert!(classes_table.contains("chat") && classes_table.contains("bulk"));
        let csv = to_csv(&pts).render();
        assert_eq!(csv.lines().count(), 3); // header + 2 points
    }
}
