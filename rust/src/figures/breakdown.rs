//! Fig. 7: latency breakdown of a single DMA copy (4KB – 2MB) into the
//! control / schedule / copy / sync phases, via the traced DES — the
//! simulator equivalent of the paper's timestamp-instrumented ROCt
//! microbenchmark.

use crate::sim::command::{Addr, AtomicOp, Command};
use crate::sim::host::{ApiKind, HostOp};
use crate::sim::topology::NodeId;
use crate::sim::trace::Phase;
use crate::sim::{EngineId, Sim, SimConfig};
use crate::util::bytes::{fmt_size, size_sweep, KB, MB};

/// Phase durations of one copy at one size (ns).
#[derive(Debug, Clone, Copy)]
pub struct BreakdownRow {
    pub size: u64,
    pub control_ns: u64,
    pub schedule_ns: u64,
    pub copy_ns: u64,
    pub sync_ns: u64,
}

impl BreakdownRow {
    /// Total copy latency.
    pub fn total(&self) -> u64 {
        self.control_ns + self.schedule_ns + self.copy_ns + self.sync_ns
    }

    /// Fraction of time outside the copy phase — the paper's ~60%-at-4KB /
    /// <20%-above-1MB headline.
    pub fn non_copy_fraction(&self) -> f64 {
        1.0 - self.copy_ns as f64 / self.total() as f64
    }
}

/// Measure one GPU→GPU copy of `size` bytes with full phase tracing.
pub fn measure(size: u64) -> BreakdownRow {
    let mut sim = Sim::new(SimConfig::mi300x().traced());
    let sig = sim.alloc_signal(0);
    let e = EngineId { gpu: 0, idx: 0 };
    sim.add_host(
        vec![
            HostOp::CreateCommands {
                engine: e,
                cmds: vec![
                    Command::Copy {
                        src: Addr::new(NodeId::Gpu(0), 0),
                        dst: Addr::new(NodeId::Gpu(1), 0),
                        len: size,
                    },
                    Command::Atomic {
                        signal: sig,
                        op: AtomicOp::Add(1),
                    },
                ],
                api: ApiKind::Raw,
            },
            HostOp::RingDoorbell { engine: e },
            HostOp::WaitSignal {
                signal: sig,
                at_least: 1,
            },
        ],
        0,
    );
    let out = sim.run();
    assert!(out.deadlocked.is_empty());
    BreakdownRow {
        size,
        control_ns: sim.trace.phase_total(Phase::Control),
        schedule_ns: sim.trace.phase_total(Phase::Schedule),
        copy_ns: sim.trace.phase_total(Phase::Copy),
        sync_ns: sim.trace.phase_total(Phase::Sync),
    }
}

/// The paper's Fig. 7 size range: 4KB – 2MB.
pub fn fig7() -> Vec<BreakdownRow> {
    size_sweep(4 * KB, 2 * MB, 2).into_iter().map(measure).collect()
}

/// Render as the paper's stacked-percentage rows.
pub fn render(rows: &[BreakdownRow]) -> String {
    let mut t = crate::util::table::Table::new(vec![
        "size", "total_us", "control%", "schedule%", "copy%", "sync%", "non_copy%",
    ]);
    for r in rows {
        let tot = r.total() as f64;
        t.row(vec![
            fmt_size(r.size),
            format!("{:.2}", tot / 1e3),
            format!("{:.1}", r.control_ns as f64 / tot * 100.0),
            format!("{:.1}", r.schedule_ns as f64 / tot * 100.0),
            format!("{:.1}", r.copy_ns as f64 / tot * 100.0),
            format!("{:.1}", r.sync_ns as f64 / tot * 100.0),
            format!("{:.1}", r.non_copy_fraction() * 100.0),
        ]);
    }
    t.render()
}

/// CSV dump.
pub fn to_csv(rows: &[BreakdownRow]) -> crate::util::csv::Csv {
    let mut csv = crate::util::csv::Csv::new(vec![
        "size_bytes",
        "control_ns",
        "schedule_ns",
        "copy_ns",
        "sync_ns",
    ]);
    for r in rows {
        csv.row(vec![
            r.size.to_string(),
            r.control_ns.to_string(),
            r.schedule_ns.to_string(),
            r.copy_ns.to_string(),
            r.sync_ns.to_string(),
        ]);
    }
    csv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_shape_matches_paper() {
        let rows = fig7();
        assert_eq!(rows.len(), 10); // 4K..2M ×2
        let f4k = rows[0].non_copy_fraction();
        assert!((0.5..=0.68).contains(&f4k), "4KB non-copy {f4k}");
        let f2m = rows.last().unwrap().non_copy_fraction();
        assert!(f2m < 0.20, "2MB non-copy {f2m}");
        // Monotone: larger size → smaller non-copy share.
        for w in rows.windows(2) {
            assert!(w[1].non_copy_fraction() <= w[0].non_copy_fraction() + 1e-9);
        }
        // Ordering at small sizes: copy > schedule ≈ sync >> control.
        let r = rows[0];
        assert!(r.copy_ns > r.schedule_ns);
        assert!(r.control_ns < r.sync_ns);
    }
}
