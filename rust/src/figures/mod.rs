//! Figure/table generators: one module per paper artifact. Each produces
//! plain data structures that the bench binaries and the CLI `figures`
//! subcommand render as ASCII tables and CSV files under `results/`.

pub mod breakdown;
pub mod cluster;
pub mod cluster_breakdown;
pub mod collectives;
pub mod disagg;
pub mod faults;
pub mod power;
pub mod serving;
pub mod serving_load;
