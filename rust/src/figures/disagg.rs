//! Disaggregated prefill/decode serving sweep: TTFT and throughput for
//! colocated serving vs disaggregation with blocking or layer-pipelined
//! KV migration, across model size × P:D ratio × workload shape.
//!
//! Each [`DisaggCell`] is one (model, P:D, workload) combination; a cell
//! measures three serving runs over the same request burst:
//!
//! - `colocated` — all P+D nodes in one tensor-parallel pool, prefill and
//!   decode share GPUs and pay the per-step all-reduce.
//! - `blocking` — P prefill + D decode nodes; each prefill's KV crosses
//!   the NIC as one bulk transfer before decode can start.
//! - `layer_pipelined` — same split, but KV streams in layer-granular
//!   chunks ([`crate::kvcache::migrate`]); decode starts when layer 0
//!   lands.
//!
//! `benches/disagg.rs` asserts the acceptance bound on these points
//! (pipelined never slower than blocking, beats colocated TTFT on a
//! prefill-heavy cell) and the CLI `serve --disagg` renders them.

use crate::coordinator::config::DisaggSpec;
use crate::coordinator::{Request, ServeConfig, ServeMetrics, VirtualEngine};
use crate::kvcache::fetch::FetchImpl;
use crate::models::zoo::{LLAMA31_8B, QWEN25_0_5B};
use crate::models::ModelConfig;

/// One sweep cell: a deployment shape driven by a fixed request burst.
#[derive(Debug, Clone)]
pub struct DisaggCell {
    pub model: &'static ModelConfig,
    pub prefill_nodes: usize,
    pub decode_nodes: usize,
    /// Workload label (`prefill_heavy` / `decode_heavy`).
    pub workload: &'static str,
    pub prompt_tokens: u64,
    pub decode_tokens: u64,
    pub requests: u64,
}

/// One measured serving run within a cell.
#[derive(Debug, Clone)]
pub struct DisaggPoint {
    pub model: &'static str,
    pub mode: String,
    pub prefill_nodes: usize,
    pub decode_nodes: usize,
    pub workload: &'static str,
    pub ttft_mean_ms: f64,
    pub ttft_p95_ms: f64,
    pub tps: f64,
    pub migrations: u64,
    pub migrated_mib: f64,
    pub wall_s: f64,
}

/// The default sweep: small + large model × 1:1 and 3:1 splits ×
/// prefill-heavy (long prompts, short generations) and decode-heavy
/// (short prompts, long generations) bursts.
pub fn default_cells() -> Vec<DisaggCell> {
    let mut cells = Vec::new();
    for model in [&QWEN25_0_5B, &LLAMA31_8B] {
        for (p, d) in [(1usize, 1usize), (3, 1)] {
            cells.push(DisaggCell {
                model,
                prefill_nodes: p,
                decode_nodes: d,
                workload: "prefill_heavy",
                prompt_tokens: 4096,
                decode_tokens: 8,
                requests: 16,
            });
            cells.push(DisaggCell {
                model,
                prefill_nodes: p,
                decode_nodes: d,
                workload: "decode_heavy",
                prompt_tokens: 512,
                decode_tokens: 128,
                requests: 16,
            });
        }
    }
    cells
}

fn base_cfg(cell: &DisaggCell) -> ServeConfig {
    let mut cfg = ServeConfig::new(cell.model, FetchImpl::DmaB2b);
    cfg.gpu_blocks = 1 << 18;
    // Cold caches: every request takes the prefill path, so disagg cells
    // migrate every KV cache and colocated cells prefill in place.
    cfg.hit_rate = 0.0;
    cfg
}

fn drive(cfg: ServeConfig, cell: &DisaggCell) -> ServeMetrics {
    let mut eng = VirtualEngine::new(cfg);
    for i in 0..cell.requests {
        eng.submit(
            Request::new(i, cell.prompt_tokens, cell.decode_tokens, 0),
            false,
        );
    }
    eng.run_to_completion().clone()
}

fn point(cell: &DisaggCell, mode: &str, m: &ServeMetrics) -> DisaggPoint {
    DisaggPoint {
        model: cell.model.name,
        mode: mode.to_string(),
        prefill_nodes: cell.prefill_nodes,
        decode_nodes: cell.decode_nodes,
        workload: cell.workload,
        ttft_mean_ms: m.ttft_mean_ms(),
        ttft_p95_ms: m.ttft_p95_ms(),
        tps: m.tps(),
        migrations: m.migrations,
        migrated_mib: m.migrated_bytes as f64 / (1024.0 * 1024.0),
        wall_s: m.wall_ns as f64 / 1e9,
    }
}

/// Measure one cell's three serving runs (colocated, blocking migration,
/// layer-pipelined migration) over the identical request burst.
pub fn measure_cell(cell: &DisaggCell) -> Vec<DisaggPoint> {
    let total = cell.prefill_nodes + cell.decode_nodes;
    let colo = drive(base_cfg(cell).with_nodes(total), cell);
    let spec = DisaggSpec::new(cell.prefill_nodes, cell.decode_nodes);
    let blocking = drive(base_cfg(cell).with_disagg(spec.blocking()), cell);
    let pipelined = drive(base_cfg(cell).with_disagg(spec), cell);
    vec![
        point(cell, "colocated", &colo),
        point(cell, "blocking", &blocking),
        point(cell, "layer_pipelined", &pipelined),
    ]
}

/// Measure every cell (cells are independent virtual-time runs; this is
/// the serial loop — the bench parallelizes at the cell level if needed).
pub fn sweep(cells: &[DisaggCell]) -> Vec<DisaggPoint> {
    cells.iter().flat_map(|c| measure_cell(c)).collect()
}

/// Render the sweep table.
pub fn render(points: &[DisaggPoint]) -> String {
    let mut t = crate::util::table::Table::new(vec![
        "model",
        "p:d",
        "workload",
        "mode",
        "ttft_mean_ms",
        "ttft_p95_ms",
        "tok_s",
        "migrations",
        "migrated_MiB",
    ]);
    for p in points {
        t.row(vec![
            p.model.to_string(),
            format!("{}:{}", p.prefill_nodes, p.decode_nodes),
            p.workload.to_string(),
            p.mode.clone(),
            format!("{:.1}", p.ttft_mean_ms),
            format!("{:.1}", p.ttft_p95_ms),
            format!("{:.0}", p.tps),
            p.migrations.to_string(),
            format!("{:.1}", p.migrated_mib),
        ]);
    }
    t.render()
}

/// CSV of the sweep (one row per point).
pub fn to_csv(points: &[DisaggPoint]) -> crate::util::csv::Csv {
    let mut c = crate::util::csv::Csv::new(vec![
        "model",
        "prefill_nodes",
        "decode_nodes",
        "workload",
        "mode",
        "ttft_mean_ms",
        "ttft_p95_ms",
        "tok_s",
        "migrations",
        "migrated_mib",
        "wall_s",
    ]);
    for p in points {
        c.row(vec![
            p.model.to_string(),
            p.prefill_nodes.to_string(),
            p.decode_nodes.to_string(),
            p.workload.to_string(),
            p.mode.clone(),
            format!("{:.3}", p.ttft_mean_ms),
            format!("{:.3}", p.ttft_p95_ms),
            format!("{:.2}", p.tps),
            p.migrations.to_string(),
            format!("{:.2}", p.migrated_mib),
            format!("{:.3}", p.wall_s),
        ]);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell() -> DisaggCell {
        DisaggCell {
            model: &QWEN25_0_5B,
            prefill_nodes: 1,
            decode_nodes: 1,
            workload: "prefill_heavy",
            prompt_tokens: 4096,
            decode_tokens: 8,
            requests: 8,
        }
    }

    #[test]
    fn cell_measures_three_modes() {
        let pts = measure_cell(&cell());
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[0].mode, "colocated");
        assert_eq!(pts[0].migrations, 0);
        for p in &pts[1..] {
            assert_eq!(p.migrations, 8);
            assert!(p.migrated_mib > 0.0);
        }
        // The acceptance ordering on one prefill-heavy cell.
        assert!(pts[2].ttft_mean_ms <= pts[1].ttft_mean_ms);
    }

    #[test]
    fn render_and_csv_cover_every_point() {
        let pts = measure_cell(&cell());
        let table = render(&pts);
        assert!(table.contains("layer_pipelined") && table.contains("colocated"));
        let csv = to_csv(&pts).render();
        assert_eq!(csv.lines().count(), 4); // header + 3 modes
    }

    #[test]
    fn default_cells_cover_the_grid() {
        let cells = default_cells();
        assert_eq!(cells.len(), 8); // 2 models × 2 ratios × 2 workloads
        assert!(cells.iter().any(|c| c.prefill_nodes == 3));
        assert!(cells.iter().any(|c| c.workload == "decode_heavy"));
    }
}
