//! Cluster-level latency breakdown: critical-path attribution of the
//! hierarchical collectives (AG / AA / RS / AR) per message size — the
//! multi-node analogue of Fig. 7's single-copy phase breakdown, produced
//! by the [`crate::obs`] tracing subsystem instead of the DES phase
//! counters. Streaming schedules are pinned (Pipelined for the barriered
//! collectives, Overlapped for all-reduce) so rows compare sizes, not
//! selector policy flips.

use crate::cluster::{
    run_hier, run_hier_ar, run_hier_rs, select_allreduce, select_cluster, ClusterKind,
    ClusterTopology, HierRunOptions, InterSchedule,
};
use crate::obs::{attribute, record, Attribution, COMPONENTS};
use crate::util::bytes::{fmt_size, KB, MB};
use crate::util::csv::Csv;
use crate::util::table::Table;

/// One (collective, size) cell: end-to-end latency and its nine-way
/// critical-path partition.
#[derive(Debug, Clone)]
pub struct ClusterBreakdownRow {
    pub kind: ClusterKind,
    pub size: u64,
    pub nodes: usize,
    pub latency_ns: u64,
    pub attr: Attribution,
}

/// Trace one hierarchical collective and attribute its latency. The
/// attribution partitions the measured window exactly, so the parts sum
/// to `latency_ns` (asserted here — this is the figure's invariant).
pub fn measure(kind: ClusterKind, nodes: usize, size: u64) -> ClusterBreakdownRow {
    let cluster = ClusterTopology::mi300x(nodes);
    let size = cluster.pad_size(size);
    let opts = HierRunOptions {
        trace: true,
        ..Default::default()
    };
    record::start();
    let res = match kind {
        ClusterKind::AllGather | ClusterKind::AllToAll => {
            let mut choice = select_cluster(kind, &cluster, size);
            if nodes > 1 {
                choice.inter = InterSchedule::Pipelined;
            }
            run_hier(kind.transport(), choice, &cluster, size, &opts)
        }
        ClusterKind::ReduceScatter => {
            let mut choice = select_cluster(kind, &cluster, size);
            if nodes > 1 {
                choice.inter = InterSchedule::Pipelined;
            }
            run_hier_rs(choice, &cluster, size, &opts)
        }
        ClusterKind::AllReduce => {
            let (mut rs, mut ag) = select_allreduce(&cluster, size);
            if nodes > 1 {
                rs.inter = InterSchedule::Overlapped;
                ag.inter = InterSchedule::Overlapped;
            }
            run_hier_ar(rs, ag, &cluster, size, &opts)
        }
    };
    let trace = record::finish().expect("recorder installed above");
    let attr = attribute(&trace);
    assert_eq!(
        attr.total(),
        res.latency_ns,
        "attribution must partition the collective latency exactly"
    );
    ClusterBreakdownRow {
        kind,
        size,
        nodes,
        latency_ns: res.latency_ns,
        attr,
    }
}

/// Default figure: all four collectives × a small size ladder on 2 nodes.
pub fn fig_cluster_breakdown(sizes: Option<Vec<u64>>) -> Vec<ClusterBreakdownRow> {
    let sizes = sizes.unwrap_or_else(|| vec![64 * KB, MB, 16 * MB]);
    let mut rows = Vec::new();
    for kind in [
        ClusterKind::AllGather,
        ClusterKind::AllToAll,
        ClusterKind::ReduceScatter,
        ClusterKind::AllReduce,
    ] {
        for &size in &sizes {
            rows.push(measure(kind, 2, size));
        }
    }
    rows
}

/// ASCII table: one row per (collective, size), one percentage column per
/// attribution component.
pub fn render(rows: &[ClusterBreakdownRow]) -> String {
    let mut header = vec!["collective".to_string(), "size".to_string(), "us".to_string()];
    header.extend(COMPONENTS.iter().map(|c| format!("{}%", c.name())));
    let mut t = Table::new(header);
    for r in rows {
        let mut cells = vec![
            r.kind.name().to_string(),
            fmt_size(r.size),
            format!("{:.1}", r.latency_ns as f64 / 1e3),
        ];
        for c in COMPONENTS {
            let pct = if r.latency_ns == 0 {
                0.0
            } else {
                r.attr.get(c) as f64 * 100.0 / r.latency_ns as f64
            };
            cells.push(format!("{pct:.1}"));
        }
        t.row(cells);
    }
    t.render()
}

/// CSV: absolute per-component ns for plotting stacked bars.
pub fn to_csv(rows: &[ClusterBreakdownRow]) -> Csv {
    let mut header = vec![
        "collective".to_string(),
        "size_bytes".to_string(),
        "nodes".to_string(),
        "latency_ns".to_string(),
    ];
    header.extend(COMPONENTS.iter().map(|c| format!("{}_ns", c.name())));
    let mut csv = Csv::new(header);
    for r in rows {
        let mut cells = vec![
            r.kind.name().to_string(),
            r.size.to_string(),
            r.nodes.to_string(),
            r.latency_ns.to_string(),
        ];
        cells.extend(COMPONENTS.iter().map(|&c| r.attr.get(c).to_string()));
        csv.row(cells);
    }
    csv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parts_sum_to_latency_for_every_kind() {
        for kind in [
            ClusterKind::AllGather,
            ClusterKind::AllToAll,
            ClusterKind::ReduceScatter,
            ClusterKind::AllReduce,
        ] {
            // measure() asserts attr.total() == latency internally.
            let row = measure(kind, 2, 256 * KB);
            assert!(row.latency_ns > 0);
            // A multi-node collective always has NIC time on the path.
            assert!(row.attr.get(crate::obs::Component::Nic) > 0, "{kind:?}");
        }
    }

    #[test]
    fn render_and_csv_shapes() {
        let rows = fig_cluster_breakdown(Some(vec![64 * KB]));
        assert_eq!(rows.len(), 4);
        let s = render(&rows);
        assert!(s.contains("allgather") && s.contains("nic%"));
        let csv = to_csv(&rows).render();
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.starts_with("collective,size_bytes,nodes,latency_ns,control_ns"));
    }
}
