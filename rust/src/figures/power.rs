//! Fig. 15: total GPU power of the best DMA collective vs CU-based RCCL
//! for all-gather across sizes, via the component power model fed by DES
//! activity (DMA side) and the RCCL activity model (CU side).

use crate::collectives::{select_variant, CollectiveKind, CollectiveRunner, RunOptions};
use crate::rccl::RcclModel;
use crate::sim::power::{PowerModel, PowerSample};
use crate::sim::SimConfig;
use crate::util::bytes::{fmt_size, size_sweep, GB, KB};

/// One power-comparison row.
#[derive(Debug, Clone)]
pub struct PowerRow {
    pub size: u64,
    pub dma_variant: String,
    pub dma: PowerSample,
    pub rccl: PowerSample,
}

impl PowerRow {
    /// DMA power saving vs RCCL (fraction; positive = DMA cheaper).
    pub fn saving(&self) -> f64 {
        1.0 - self.dma.total() / self.rccl.total()
    }
}

/// Sweep 16KB – 1GB (the paper's Fig. 15 x-range).
pub fn fig15(sizes: Option<Vec<u64>>) -> Vec<PowerRow> {
    let sizes = sizes.unwrap_or_else(|| size_sweep(16 * KB, GB, 2));
    let pm = PowerModel::default();
    let rccl = RcclModel::default();
    let opts = RunOptions {
        sim: SimConfig::mi300x(),
        verify: false,
    };
    let kind = CollectiveKind::AllGather;
    // One reset-reused simulator for the whole sweep (§Perf pass).
    let mut runner = CollectiveRunner::new(&opts);
    sizes
        .into_iter()
        .map(|size| {
            let v = select_variant(kind, size);
            let r = runner.run(kind, v, size);
            // DES activity is platform-wide; the power model (like the
            // paper's Fig. 15) reports per-GPU watts.
            let n = opts.sim.topology.num_gpus as f64;
            let mut a = r.activity.clone();
            a.engine_busy_ns /= n;
            a.engines_used = (a.engines_used as f64 / n).ceil() as usize;
            a.hbm_bytes /= n;
            a.link_bytes /= n;
            let dma = pm.evaluate(&a);
            let rccl_s = pm.evaluate(&rccl.activity(kind, &opts.sim.topology, size));
            PowerRow {
                size,
                dma_variant: v.name(),
                dma,
                rccl: rccl_s,
            }
        })
        .collect()
}

/// Render the comparison.
pub fn render(rows: &[PowerRow]) -> String {
    let mut t = crate::util::table::Table::new(vec![
        "size",
        "dma_variant",
        "dma_W",
        "dma_xcd_W",
        "rccl_W",
        "rccl_xcd_W",
        "saving%",
    ]);
    for r in rows {
        t.row(vec![
            fmt_size(r.size),
            r.dma_variant.clone(),
            format!("{:.0}", r.dma.total()),
            format!("{:.0}", r.dma.xcd_w),
            format!("{:.0}", r.rccl.total()),
            format!("{:.0}", r.rccl.xcd_w),
            format!("{:.1}", r.saving() * 100.0),
        ]);
    }
    t.render()
}

/// CSV dump.
pub fn to_csv(rows: &[PowerRow]) -> crate::util::csv::Csv {
    let mut csv = crate::util::csv::Csv::new(vec![
        "size_bytes",
        "dma_variant",
        "dma_total_w",
        "dma_xcd_w",
        "dma_hbm_w",
        "rccl_total_w",
        "rccl_xcd_w",
        "rccl_hbm_w",
    ]);
    for r in rows {
        csv.row(vec![
            r.size.to_string(),
            r.dma_variant.clone(),
            format!("{:.1}", r.dma.total()),
            format!("{:.1}", r.dma.xcd_w),
            format!("{:.1}", r.dma.hbm_w),
            format!("{:.1}", r.rccl.total()),
            format!("{:.1}", r.rccl.xcd_w),
            format!("{:.1}", r.rccl.hbm_w),
        ]);
    }
    csv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MB;

    #[test]
    fn bandwidth_bound_sizes_save_power() {
        let rows = fig15(Some(vec![64 * MB, 256 * MB]));
        for r in &rows {
            assert!(
                r.saving() > 0.15,
                "expected ≥15% saving at {}: {:.1}%",
                fmt_size(r.size),
                r.saving() * 100.0
            );
            // XCD power is the driver (paper: 3.7× less XCD power).
            assert!(r.rccl.xcd_w > 3.0 * r.dma.xcd_w);
        }
    }

    #[test]
    fn latency_bound_savings_shrink() {
        let small = &fig15(Some(vec![32 * KB]))[0];
        let large = &fig15(Some(vec![256 * MB]))[0];
        assert!(small.saving() < large.saving());
    }
}
