//! Fig. 15: total GPU power of the best DMA collective vs CU-based RCCL
//! for all-gather across sizes, via the component power model fed by DES
//! activity (DMA side) and the RCCL activity model (CU side) — plus the
//! cluster extension: per-byte NIC power for cross-node KV migration
//! ([`migration_power`]), so disaggregated serving's energy cost shows up
//! in the power tables, not just the latency sweeps.

use crate::cluster::topology::NicModel;
use crate::collectives::{select_variant, CollectiveKind, CollectiveRunner, RunOptions};
use crate::kvcache::fetch::FetchImpl;
use crate::kvcache::{BlockLayout, MigrateSchedule, Migrator};
use crate::models::zoo::{LLAMA31_8B, QWEN25_0_5B};
use crate::rccl::RcclModel;
use crate::sim::power::{Activity, PowerModel, PowerSample};
use crate::sim::SimConfig;
use crate::util::bytes::{fmt_size, size_sweep, GB, KB};

/// One power-comparison row.
#[derive(Debug, Clone)]
pub struct PowerRow {
    pub size: u64,
    pub dma_variant: String,
    pub dma: PowerSample,
    pub rccl: PowerSample,
}

impl PowerRow {
    /// DMA power saving vs RCCL (fraction; positive = DMA cheaper).
    pub fn saving(&self) -> f64 {
        1.0 - self.dma.total() / self.rccl.total()
    }
}

/// Sweep 16KB – 1GB (the paper's Fig. 15 x-range).
pub fn fig15(sizes: Option<Vec<u64>>) -> Vec<PowerRow> {
    let sizes = sizes.unwrap_or_else(|| size_sweep(16 * KB, GB, 2));
    let pm = PowerModel::default();
    let rccl = RcclModel::default();
    let opts = RunOptions {
        sim: SimConfig::mi300x(),
        verify: false,
    };
    let kind = CollectiveKind::AllGather;
    // One reset-reused simulator for the whole sweep (§Perf pass).
    let mut runner = CollectiveRunner::new(&opts);
    sizes
        .into_iter()
        .map(|size| {
            let v = select_variant(kind, size);
            let r = runner.run(kind, v, size);
            // DES activity is platform-wide; the power model (like the
            // paper's Fig. 15) reports per-GPU watts.
            let n = opts.sim.topology.num_gpus as f64;
            let mut a = r.activity.clone();
            a.engine_busy_ns /= n;
            a.engines_used = (a.engines_used as f64 / n).ceil() as usize;
            a.hbm_bytes /= n;
            a.link_bytes /= n;
            let dma = pm.evaluate(&a);
            let rccl_s = pm.evaluate(&rccl.activity(kind, &opts.sim.topology, size));
            PowerRow {
                size,
                dma_variant: v.name(),
                dma,
                rccl: rccl_s,
            }
        })
        .collect()
}

/// Render the comparison.
pub fn render(rows: &[PowerRow]) -> String {
    let mut t = crate::util::table::Table::new(vec![
        "size",
        "dma_variant",
        "dma_W",
        "dma_xcd_W",
        "rccl_W",
        "rccl_xcd_W",
        "saving%",
    ]);
    for r in rows {
        t.row(vec![
            fmt_size(r.size),
            r.dma_variant.clone(),
            format!("{:.0}", r.dma.total()),
            format!("{:.0}", r.dma.xcd_w),
            format!("{:.0}", r.rccl.total()),
            format!("{:.0}", r.rccl.xcd_w),
            format!("{:.1}", r.saving() * 100.0),
        ]);
    }
    t.render()
}

/// CSV dump.
pub fn to_csv(rows: &[PowerRow]) -> crate::util::csv::Csv {
    let mut csv = crate::util::csv::Csv::new(vec![
        "size_bytes",
        "dma_variant",
        "dma_total_w",
        "dma_xcd_w",
        "dma_hbm_w",
        "rccl_total_w",
        "rccl_xcd_w",
        "rccl_hbm_w",
    ]);
    for r in rows {
        csv.row(vec![
            r.size.to_string(),
            r.dma_variant.clone(),
            format!("{:.1}", r.dma.total()),
            format!("{:.1}", r.dma.xcd_w),
            format!("{:.1}", r.dma.hbm_w),
            format!("{:.1}", r.rccl.total()),
            format!("{:.1}", r.rccl.xcd_w),
            format!("{:.1}", r.rccl.hbm_w),
        ]);
    }
    csv
}

/// One cluster-power row: average power while a KV migration drains,
/// including the NIC watts the migration puts on the wire.
#[derive(Debug, Clone)]
pub struct MigrationPowerRow {
    pub model: &'static str,
    pub schedule: MigrateSchedule,
    /// KV bytes migrated.
    pub bytes: u64,
    /// Migration makespan (ns).
    pub total_ns: u64,
    pub sample: PowerSample,
}

impl MigrationPowerRow {
    /// Fraction of total power burned by the NIC.
    pub fn nic_share(&self) -> f64 {
        self.sample.nic_w / self.sample.total()
    }
}

/// Cluster power table: both migration schedules for a small and a large
/// model at a fixed prompt footprint (`n_blocks` KV blocks). The DMA legs
/// charge engine/PCIe/HBM activity; the NIC leg charges per-byte NIC
/// power ([`PowerModel::p_nic_per_gbps`]).
pub fn migration_power(n_blocks: u64) -> Vec<MigrationPowerRow> {
    let pm = PowerModel::default();
    let nic = NicModel::default();
    let mut mig = Migrator::new();
    let mut rows = Vec::new();
    for model in [&QWEN25_0_5B, &LLAMA31_8B] {
        let layout = BlockLayout::new(model, 16);
        for schedule in [MigrateSchedule::Blocking, MigrateSchedule::LayerPipelined] {
            let out = mig.cost(
                &layout,
                model.layers,
                FetchImpl::DmaB2b,
                &nic,
                n_blocks,
                schedule,
            );
            // Per migrated byte: one D2H + one H2D PCIe crossing, a GPU
            // HBM read on the prefill node and a write on the decode
            // node, and exactly one NIC crossing.
            let a = Activity {
                duration_ns: out.total_ns as f64,
                engine_busy_ns: (out.save_ns + out.fetch_ns) as f64,
                engines_used: 1,
                cu_busy_ns: 0.0,
                hbm_bytes: 2.0 * out.bytes as f64,
                link_bytes: 2.0 * out.bytes as f64,
                nic_bytes: out.bytes as f64,
            };
            rows.push(MigrationPowerRow {
                model: model.name,
                schedule,
                bytes: out.bytes,
                total_ns: out.total_ns,
                sample: pm.evaluate(&a),
            });
        }
    }
    rows
}

/// Render the cluster migration power table.
pub fn render_migration(rows: &[MigrationPowerRow]) -> String {
    let mut t = crate::util::table::Table::new(vec![
        "model",
        "schedule",
        "kv_bytes",
        "mig_ms",
        "total_W",
        "nic_W",
        "nic_share%",
    ]);
    for r in rows {
        t.row(vec![
            r.model.to_string(),
            r.schedule.name().to_string(),
            fmt_size(r.bytes),
            format!("{:.2}", r.total_ns as f64 / 1e6),
            format!("{:.0}", r.sample.total()),
            format!("{:.1}", r.sample.nic_w),
            format!("{:.1}", r.nic_share() * 100.0),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MB;

    #[test]
    fn bandwidth_bound_sizes_save_power() {
        let rows = fig15(Some(vec![64 * MB, 256 * MB]));
        for r in &rows {
            assert!(
                r.saving() > 0.15,
                "expected ≥15% saving at {}: {:.1}%",
                fmt_size(r.size),
                r.saving() * 100.0
            );
            // XCD power is the driver (paper: 3.7× less XCD power).
            assert!(r.rccl.xcd_w > 3.0 * r.dma.xcd_w);
        }
    }

    #[test]
    fn latency_bound_savings_shrink() {
        let small = &fig15(Some(vec![32 * KB]))[0];
        let large = &fig15(Some(vec![256 * MB]))[0];
        assert!(small.saving() < large.saving());
    }

    #[test]
    fn migration_power_surfaces_nic_watts() {
        let rows = migration_power(256);
        assert_eq!(rows.len(), 4); // 2 models × 2 schedules
        for r in &rows {
            assert!(r.sample.nic_w > 0.0, "{} {:?}: no NIC watts", r.model, r.schedule);
            assert!(r.nic_share() > 0.0 && r.nic_share() < 1.0);
        }
        // Same bytes either schedule; the streamed schedule finishes no
        // later, so its sustained NIC draw is at least as high.
        assert_eq!(rows[0].bytes, rows[1].bytes);
        assert!(rows[1].total_ns <= rows[0].total_ns);
        assert!(rows[1].sample.nic_w >= rows[0].sample.nic_w);
        let table = render_migration(&rows);
        assert!(table.contains("nic_W"));
        assert!(table.contains("layer_pipelined"));
    }
}
