//! Figs. 1/13/14 + Tables 2/3: DMA collective variants vs RCCL across the
//! size spectrum (1KB – 4GB), reported as speedup of DMA over RCCL
//! (values < 1 are slowdowns, exactly as the paper plots).

use crate::collectives::selector::{calibrate, ranges, SweepPoint};
use crate::collectives::{CollectiveKind, CollectiveRunner, RunOptions, Variant};
use crate::rccl::RcclModel;
use crate::sim::SimConfig;
use crate::util::bytes::{fmt_size, size_sweep, GB, KB, MB};
use crate::util::stats::geomean;

/// One sweep row: a size with RCCL latency and per-variant DMA latencies.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub size: u64,
    pub rccl_ns: f64,
    /// (variant, dma latency ns, speedup vs RCCL).
    pub variants: Vec<(Variant, u64, f64)>,
}

impl SweepRow {
    /// Speedup of a given variant (panics if absent).
    pub fn speedup(&self, v: Variant) -> f64 {
        self.variants
            .iter()
            .find(|(x, _, _)| *x == v)
            .map(|&(_, _, s)| s)
            .unwrap_or_else(|| panic!("variant {} not in row", v.name()))
    }

    /// Best DMA speedup in this row.
    pub fn best(&self) -> (Variant, f64) {
        self.variants
            .iter()
            .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .map(|&(v, _, s)| (v, s))
            .unwrap()
    }
}

/// Run the full sweep for `kind` over `sizes` (default: 1KB..4GB ×2).
pub fn sweep(kind: CollectiveKind, sizes: Option<Vec<u64>>) -> Vec<SweepRow> {
    let sizes = sizes.unwrap_or_else(|| size_sweep(KB, 4 * GB, 2));
    let rccl = RcclModel::default();
    let opts = RunOptions {
        sim: SimConfig::mi300x(),
        verify: false,
    };
    // One simulator reused (reset) across every (size, variant) episode;
    // plans come from the cross-episode cache (§Perf pass).
    let mut runner = CollectiveRunner::new(&opts);
    let variants = Variant::all_for(kind);
    sizes
        .into_iter()
        .map(|size| {
            let rccl_ns = rccl.latency_ns(kind, &opts.sim.topology, size);
            let variants = variants
                .iter()
                .map(|&v| {
                    let r = runner.run(kind, v, size);
                    (v, r.latency_ns, rccl_ns / r.latency_ns as f64)
                })
                .collect();
            SweepRow {
                size,
                rccl_ns,
                variants,
            }
        })
        .collect()
}

/// Geomean speedup of `v` over rows with `size < below` (paper-style
/// "geomean for sizes up to X" summaries).
pub fn geomean_speedup(rows: &[SweepRow], v: Variant, below: u64) -> f64 {
    let xs: Vec<f64> = rows
        .iter()
        .filter(|r| r.size < below)
        .map(|r| r.speedup(v))
        .collect();
    geomean(&xs)
}

/// Geomean of the per-size BEST DMA variant (the paper's bottom line:
/// "30% slower geomean for AG / 20% faster for AA").
pub fn geomean_best(rows: &[SweepRow], below: u64) -> f64 {
    let xs: Vec<f64> = rows
        .iter()
        .filter(|r| r.size < below)
        .map(|r| r.best().1)
        .collect();
    geomean(&xs)
}

/// Derive Table 2/3 rows from a sweep: contiguous size ranges with the
/// empirically best variant.
pub fn best_table(rows: &[SweepRow]) -> Vec<(u64, u64, Variant)> {
    let pts: Vec<SweepPoint> = rows
        .iter()
        .flat_map(|r| {
            r.variants.iter().map(|&(v, lat, _)| SweepPoint {
                size: r.size,
                variant: v,
                latency_ns: lat,
            })
        })
        .collect();
    ranges(&calibrate(&pts))
}

/// Render a sweep as the paper's figure rows (size × variant speedups).
pub fn render(kind: CollectiveKind, rows: &[SweepRow]) -> String {
    let variants = Variant::all_for(kind);
    let mut header = vec!["size".to_string(), "rccl_us".to_string()];
    header.extend(variants.iter().map(|v| v.name()));
    let mut t = crate::util::table::Table::new(header);
    for r in rows {
        let mut cells = vec![fmt_size(r.size), format!("{:.1}", r.rccl_ns / 1e3)];
        cells.extend(variants.iter().map(|&v| format!("{:.2}", r.speedup(v))));
        t.row(cells);
    }
    t.render()
}

/// CSV dump of a sweep.
pub fn to_csv(kind: CollectiveKind, rows: &[SweepRow]) -> crate::util::csv::Csv {
    let variants = Variant::all_for(kind);
    let mut header = vec!["size_bytes".to_string(), "rccl_ns".to_string()];
    for v in &variants {
        header.push(format!("{}_ns", v.name()));
        header.push(format!("{}_speedup", v.name()));
    }
    let mut csv = crate::util::csv::Csv::new(header);
    for r in rows {
        let mut cells = vec![r.size.to_string(), format!("{:.0}", r.rccl_ns)];
        for &v in &variants {
            let (_, lat, sp) = r
                .variants
                .iter()
                .find(|(x, _, _)| *x == v)
                .copied()
                .unwrap();
            cells.push(lat.to_string());
            cells.push(format!("{sp:.4}"));
        }
        csv.row(cells);
    }
    csv
}

/// The paper's headline windows, used by calibration tests and benches.
pub const LATENCY_BOUND_CEILING: u64 = 32 * MB;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Strategy;

    #[test]
    fn sweep_produces_all_variants() {
        let rows = sweep(CollectiveKind::AllGather, Some(vec![4 * KB, 4 * MB]));
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].variants.len(), 6);
        assert!(rows[0].rccl_ns > 0.0);
        // speedups consistent: speedup = rccl / dma.
        for r in &rows {
            for &(_, lat, sp) in &r.variants {
                assert!((sp - r.rccl_ns / lat as f64).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn best_table_collapses() {
        let rows = sweep(
            CollectiveKind::AllGather,
            Some(vec![4 * KB, 8 * KB, 64 * MB, 128 * MB]),
        );
        let t = best_table(&rows);
        assert!(!t.is_empty());
        // Small sizes should not pick plain pcpy.
        let (_, _, v) = t[0];
        assert_ne!((v.strategy, v.prelaunch), (Strategy::Pcpy, false));
    }
}
