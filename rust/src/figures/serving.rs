//! Figs. 16/17 + §5.3.3 sweeps: LLM-inference benefits of the optimized
//! DMA KV fetch across the paper's model zoo.

use crate::coordinator::request::Request;
use crate::coordinator::{ServeConfig, VirtualEngine};
use crate::kvcache::fetch::FetchImpl;
use crate::models::{ModelConfig, ALL_MODELS};

/// Fig. 16 row: TTFT speedups of b2b DMA over baseline DMA for one
/// (model, prefill) cell.
#[derive(Debug, Clone)]
pub struct TtftRow {
    pub model: &'static str,
    pub prefill: u64,
    pub base_gpu_ms: f64,
    pub b2b_gpu_ms: f64,
    pub kernel_gpu_ms: f64,
    pub speedup_gpu: f64,
    pub base_total_ms: f64,
    pub b2b_total_ms: f64,
    pub kernel_total_ms: f64,
    pub speedup_total: f64,
}

/// Generate Fig. 16 for the given models × prefill lengths.
pub fn fig16(models: &[&'static ModelConfig], prefills: &[u64]) -> Vec<TtftRow> {
    let mut rows = Vec::new();
    for &m in models {
        for &p in prefills {
            let base =
                VirtualEngine::measure_ttft(&ServeConfig::new(m, FetchImpl::DmaBaseline), p);
            let b2b = VirtualEngine::measure_ttft(&ServeConfig::new(m, FetchImpl::DmaB2b), p);
            let kern = VirtualEngine::measure_ttft(&ServeConfig::new(m, FetchImpl::Kernel), p);
            rows.push(TtftRow {
                model: m.name,
                prefill: p,
                base_gpu_ms: base.0 as f64 / 1e6,
                b2b_gpu_ms: b2b.0 as f64 / 1e6,
                kernel_gpu_ms: kern.0 as f64 / 1e6,
                speedup_gpu: base.0 as f64 / b2b.0 as f64,
                base_total_ms: base.1 as f64 / 1e6,
                b2b_total_ms: b2b.1 as f64 / 1e6,
                kernel_total_ms: kern.1 as f64 / 1e6,
                speedup_total: base.1 as f64 / b2b.1 as f64,
            });
        }
    }
    rows
}

/// Default Fig. 16: full zoo × {4096, 8192}.
pub fn fig16_default() -> Vec<TtftRow> {
    fig16(ALL_MODELS, &[4096, 8192])
}

/// Fig. 17 row: throughput of b2b vs baseline vs kernel fetch for one
/// (model, prefill) cell at a given hit rate.
#[derive(Debug, Clone)]
pub struct TputRow {
    pub model: &'static str,
    pub prefill: u64,
    pub hit_rate: f64,
    pub base_tps: f64,
    pub b2b_tps: f64,
    pub kernel_tps: f64,
    /// b2b over baseline (the Fig. 17 bar).
    pub gain: f64,
    /// b2b over kernel (§5.3.3 "DMA vs kernel").
    pub gain_vs_kernel: f64,
}

/// Run the throughput workload: `n` simultaneous requests of `prefill`
/// tokens, `decode` output tokens each (paper: 2000 requests; callers can
/// scale down for CI).
pub fn throughput(
    model: &'static ModelConfig,
    prefill: u64,
    n: u64,
    decode: u64,
    hit_rate: f64,
) -> TputRow {
    let run = |fetch: FetchImpl| -> f64 {
        let mut cfg = ServeConfig::new(model, fetch);
        cfg.hit_rate = hit_rate;
        // Size the pool for the batch, not the whole backlog.
        let layout = crate::kvcache::BlockLayout::new(model, cfg.block_tokens);
        cfg.gpu_blocks = layout.blocks_for(prefill + decode) * (cfg.max_batch as u64 + 8);
        let mut eng = VirtualEngine::new(cfg);
        for i in 0..n {
            eng.submit(Request::new(i, prefill, decode, 0), true);
        }
        let m = eng.run_to_completion();
        assert_eq!(m.finished, n, "lost requests");
        m.tps()
    };
    let base = run(FetchImpl::DmaBaseline);
    let b2b = run(FetchImpl::DmaB2b);
    let kern = run(FetchImpl::Kernel);
    TputRow {
        model: model.name,
        prefill,
        hit_rate,
        base_tps: base,
        b2b_tps: b2b,
        kernel_tps: kern,
        gain: b2b / base,
        gain_vs_kernel: b2b / kern,
    }
}

/// Render Fig. 16.
pub fn render_fig16(rows: &[TtftRow]) -> String {
    let mut t = crate::util::table::Table::new(vec![
        "model",
        "prefill",
        "base_gpu_ms",
        "b2b_gpu_ms",
        "kern_gpu_ms",
        "TTFT_GPU x",
        "TTFT_total x",
    ]);
    for r in rows {
        t.row(vec![
            r.model.to_string(),
            r.prefill.to_string(),
            format!("{:.2}", r.base_gpu_ms),
            format!("{:.2}", r.b2b_gpu_ms),
            format!("{:.2}", r.kernel_gpu_ms),
            format!("{:.2}", r.speedup_gpu),
            format!("{:.2}", r.speedup_total),
        ]);
    }
    t.render()
}

/// Render Fig. 17 (+hit-rate sweeps).
pub fn render_fig17(rows: &[TputRow]) -> String {
    let mut t = crate::util::table::Table::new(vec![
        "model",
        "prefill",
        "hit%",
        "base_tps",
        "b2b_tps",
        "kern_tps",
        "b2b/base",
        "b2b/kern",
    ]);
    for r in rows {
        t.row(vec![
            r.model.to_string(),
            r.prefill.to_string(),
            format!("{:.0}", r.hit_rate * 100.0),
            format!("{:.0}", r.base_tps),
            format!("{:.0}", r.b2b_tps),
            format!("{:.0}", r.kernel_tps),
            format!("{:.2}", r.gain),
            format!("{:.2}", r.gain_vs_kernel),
        ]);
    }
    t.render()
}

/// CSV for Fig. 16.
pub fn fig16_csv(rows: &[TtftRow]) -> crate::util::csv::Csv {
    let mut c = crate::util::csv::Csv::new(vec![
        "model",
        "prefill",
        "base_gpu_ms",
        "b2b_gpu_ms",
        "kernel_gpu_ms",
        "base_total_ms",
        "b2b_total_ms",
        "kernel_total_ms",
    ]);
    for r in rows {
        c.row(vec![
            r.model.to_string(),
            r.prefill.to_string(),
            format!("{:.3}", r.base_gpu_ms),
            format!("{:.3}", r.b2b_gpu_ms),
            format!("{:.3}", r.kernel_gpu_ms),
            format!("{:.3}", r.base_total_ms),
            format!("{:.3}", r.b2b_total_ms),
            format!("{:.3}", r.kernel_total_ms),
        ]);
    }
    c
}

/// CSV for Fig. 17.
pub fn fig17_csv(rows: &[TputRow]) -> crate::util::csv::Csv {
    let mut c = crate::util::csv::Csv::new(vec![
        "model", "prefill", "hit_rate", "base_tps", "b2b_tps", "kernel_tps",
    ]);
    for r in rows {
        c.row(vec![
            r.model.to_string(),
            r.prefill.to_string(),
            format!("{:.2}", r.hit_rate),
            format!("{:.1}", r.base_tps),
            format!("{:.1}", r.b2b_tps),
            format!("{:.1}", r.kernel_tps),
        ]);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{LLAMA31_8B, QWEN25_0_5B};

    #[test]
    fn fig16_shape() {
        let rows = fig16(&[&QWEN25_0_5B, &LLAMA31_8B], &[4096]);
        // Small model gains more (paper: "benefits are higher for smaller
        // models").
        assert!(rows[0].speedup_gpu > rows[1].speedup_gpu);
        // Headline band: up to ~2.29× GPU / ~1.5× total for the smallest.
        assert!((1.8..2.8).contains(&rows[0].speedup_gpu), "{}", rows[0].speedup_gpu);
        assert!((1.2..1.9).contains(&rows[0].speedup_total), "{}", rows[0].speedup_total);
        // No regressions for the big model.
        assert!(rows[1].speedup_gpu >= 0.95);
    }

    #[test]
    fn fig16_longer_prompts_gain_more() {
        let rows = fig16(&[&QWEN25_0_5B], &[4096, 8192]);
        assert!(rows[1].speedup_gpu >= rows[0].speedup_gpu * 0.98);
    }

    #[test]
    fn fig17_throughput_gain() {
        let r = throughput(&QWEN25_0_5B, 1024, 96, 16, 1.0);
        assert!(r.gain > 1.15, "b2b/base = {:.2}", r.gain);
        assert!(r.gain_vs_kernel > 1.0, "b2b/kern = {:.2}", r.gain_vs_kernel);
    }

    #[test]
    fn hit_sweep_reduces_gain() {
        let full = throughput(&QWEN25_0_5B, 1024, 64, 16, 1.0);
        let half = throughput(&QWEN25_0_5B, 1024, 64, 16, 0.5);
        assert!(half.gain <= full.gain * 1.05, "full {} half {}", full.gain, half.gain);
    }
}
