//! Degraded-vs-healthy serving: canned fault scenarios replayed under
//! the degradation-aware policy and the degradation-blind baseline.
//!
//! Every scenario is a [`FaultSpec`] preset materialized by the engine
//! from the serving seed ([`crate::cluster::FaultPlan`]), so one row is
//! one deterministic run. The figure's claim mirrors the subsystem's
//! acceptance gate: under a degraded fleet the aware policy (re-select,
//! drain, shed, preempt) keeps strictly more of the SLO'd chat class
//! inside its latency budget than the blind baseline, and a healthy
//! (empty) fault plan replays the no-faults run bit for bit.

use crate::cluster::FaultSpec;
use crate::coordinator::workload::{default_tenants, drive, ArrivalProcess, WorkloadSpec};
use crate::coordinator::{DegradePolicy, ServeConfig, ServeMetrics};
use crate::models::ModelConfig;

use super::serving_load;

/// The canned scenarios the figure (and the chaos smoke) replays: the
/// healthy baseline plus three degraded fleets.
pub const SCENARIOS: [&str; 4] = ["healthy", "nic-brownout", "flaky-links", "straggler"];

/// One (scenario, policy) serving run.
#[derive(Debug, Clone)]
pub struct FaultPoint {
    pub scenario: String,
    /// `-` (healthy), `blind`, or `aware`.
    pub policy: String,
    pub rate_rps: f64,
    pub finished: u64,
    /// SLO attainment of the chat (SLO'd) class.
    pub chat_attainment: f64,
    /// Overall SLO attainment.
    pub attainment: f64,
    pub goodput_rps: f64,
    pub ttft_p99_ms: f64,
    pub retries: u64,
    pub timeouts: u64,
    pub shed: u64,
    pub preemptions: u64,
    pub drained: u64,
    pub wall_s: f64,
}

/// Attainment of the first SLO-carrying class (the chat tenant in the
/// default mix); NaN when no such class finished anything.
pub fn chat_attainment(m: &ServeMetrics) -> f64 {
    m.per_class
        .iter()
        .find(|c| c.slo.is_some())
        .map(|c| c.attainment())
        .unwrap_or(f64::NAN)
}

fn run(cfg: &ServeConfig, requests: u64, rate_rps: f64, seed: u64) -> ServeMetrics {
    let spec = WorkloadSpec {
        process: ArrivalProcess::Poisson { rate_rps },
        classes: default_tenants(),
        requests,
        seed,
    };
    drive(cfg, &spec)
}

fn point(scenario: &str, policy: &str, rate_rps: f64, m: &ServeMetrics) -> FaultPoint {
    FaultPoint {
        scenario: scenario.to_string(),
        policy: policy.to_string(),
        rate_rps,
        finished: m.finished,
        chat_attainment: chat_attainment(m),
        attainment: m.slo_attainment(),
        goodput_rps: m.goodput_rps(),
        ttft_p99_ms: m.ttft_p99_ms(),
        retries: m.retries,
        timeouts: m.timeouts,
        shed: m.shed,
        preemptions: m.preemptions,
        drained: m.drained_nodes,
        wall_s: m.wall_ns as f64 / 1e9,
    }
}

/// Run every scenario: one healthy row, then a blind and an aware row
/// per degraded scenario, all at the same offered rate (a fixed fraction
/// of the healthy fleet's closed-loop capacity, so degradation shows up
/// as lost attainment rather than an empty queue).
pub fn fig_faults(
    model: &'static ModelConfig,
    nodes: usize,
    requests: u64,
    seed: u64,
) -> Vec<FaultPoint> {
    let cfg = serving_load::serve_config(model, nodes, true);
    let classes = default_tenants();
    let cap = serving_load::estimate_capacity_rps(&cfg, &classes, requests.clamp(32, 128), seed);
    let rate = 0.6 * cap;
    let mut rows = Vec::new();
    for name in SCENARIOS {
        let spec = FaultSpec::preset(name).expect("known scenario");
        if spec.is_healthy() {
            rows.push(point("healthy", "-", rate, &run(&cfg, requests, rate, seed)));
            continue;
        }
        let policies = [(DegradePolicy::blind(), "blind"), (DegradePolicy::aware(), "aware")];
        for (policy, label) in policies {
            let c = cfg.clone().with_faults(spec.clone()).with_degrade(policy);
            rows.push(point(name, label, rate, &run(&c, requests, rate, seed)));
        }
    }
    rows
}

/// The zero-perturbation contract, run live: a config carrying an empty
/// (all-healthy) fault spec must replay the fault-free run bit for bit.
pub fn healthy_replay_ok(
    model: &'static ModelConfig,
    nodes: usize,
    requests: u64,
    seed: u64,
) -> bool {
    let cfg = serving_load::serve_config(model, nodes, true);
    let rate = 400.0;
    let a = run(&cfg, requests, rate, seed);
    let faulted = cfg.with_faults(FaultSpec::default());
    let b = run(&faulted, requests, rate, seed);
    a.wall_ns == b.wall_ns
        && a.ttft_ns == b.ttft_ns
        && a.tpot_ns == b.tpot_ns
        && b.retries == 0
        && b.shed == 0
        && b.drained_nodes == 0
}

/// Render the degraded-vs-healthy attainment table.
pub fn render(points: &[FaultPoint]) -> String {
    let mut t = crate::util::table::Table::new(vec![
        "scenario",
        "policy",
        "rate_rps",
        "reqs",
        "chat_slo%",
        "slo%",
        "goodput_rps",
        "ttft_p99_ms",
        "retries",
        "shed",
        "preempted",
        "drained",
    ]);
    for p in points {
        t.row(vec![
            p.scenario.clone(),
            p.policy.clone(),
            format!("{:.0}", p.rate_rps),
            p.finished.to_string(),
            format!("{:.1}", p.chat_attainment * 100.0),
            format!("{:.1}", p.attainment * 100.0),
            format!("{:.0}", p.goodput_rps),
            format!("{:.1}", p.ttft_p99_ms),
            p.retries.to_string(),
            p.shed.to_string(),
            p.preemptions.to_string(),
            p.drained.to_string(),
        ]);
    }
    t.render()
}

/// CSV of every (scenario, policy) run.
pub fn to_csv(points: &[FaultPoint]) -> crate::util::csv::Csv {
    let mut c = crate::util::csv::Csv::new(vec![
        "scenario",
        "policy",
        "rate_rps",
        "finished",
        "chat_attainment",
        "attainment",
        "goodput_rps",
        "ttft_p99_ms",
        "retries",
        "timeouts",
        "shed",
        "preemptions",
        "drained",
        "wall_s",
    ]);
    for p in points {
        c.row(vec![
            p.scenario.clone(),
            p.policy.clone(),
            format!("{:.2}", p.rate_rps),
            p.finished.to_string(),
            format!("{:.4}", p.chat_attainment),
            format!("{:.4}", p.attainment),
            format!("{:.2}", p.goodput_rps),
            format!("{:.3}", p.ttft_p99_ms),
            p.retries.to_string(),
            p.timeouts.to_string(),
            p.shed.to_string(),
            p.preemptions.to_string(),
            p.drained.to_string(),
            format!("{:.3}", p.wall_s),
        ]);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::QWEN25_0_5B;

    #[test]
    fn fig_rows_cover_every_scenario_and_replay_holds() {
        let rows = fig_faults(&QWEN25_0_5B, 2, 48, 7);
        // One healthy row + (blind, aware) per degraded scenario.
        assert_eq!(rows.len(), 1 + 2 * (SCENARIOS.len() - 1));
        assert!(rows.iter().all(|p| p.finished > 0));
        let healthy = &rows[0];
        assert_eq!(healthy.scenario, "healthy");
        assert_eq!((healthy.retries, healthy.shed, healthy.drained), (0, 0, 0));
        let table = render(&rows);
        assert!(table.contains("nic-brownout") && table.contains("aware"));
        let csv = to_csv(&rows).render();
        assert_eq!(csv.lines().count(), rows.len() + 1);
        assert!(healthy_replay_ok(&QWEN25_0_5B, 2, 32, 7));
    }
}
