//! Cluster scaling figures (beyond the paper): hierarchical DMA collective
//! latency across node counts (1 → 8) and sizes (1KB → 1GB) for the full
//! [`ClusterKind`] set — all-gather, all-to-all, reduce-scatter and
//! all-reduce — with the cluster-aware selector picking the configuration
//! per cell (for all-reduce: one choice per phase). The single-node column
//! reproduces the flat collective (reduce-scatter: the flat DMA+CU split),
//! so the table reads as "what scale-out costs on top of the paper's
//! numbers".

use crate::cluster::{
    run_hier, run_hier_ar, run_hier_rs, select_allreduce, select_cluster, ClusterChoice,
    ClusterKind, ClusterTopology, HierRunOptions,
};
use crate::util::bytes::{fmt_size, size_sweep, GB, KB};

/// One (node count) cell of a scaling row.
#[derive(Debug, Clone)]
pub struct ScaleCell {
    pub nodes: usize,
    /// Selector choice (the reduce-scatter phase choice for all-reduce).
    pub choice: ClusterChoice,
    /// All-reduce only: the gather-phase choice.
    pub ag_choice: Option<ClusterChoice>,
    pub latency_ns: u64,
    pub inter_ns: u64,
}

impl ScaleCell {
    /// Figure-label name of the cell's configuration (`rs+ag` composite
    /// for all-reduce).
    pub fn choice_name(&self) -> String {
        match &self.ag_choice {
            Some(ag) => format!("{}+{}", self.choice.name(), ag.name()),
            None => self.choice.name(),
        }
    }
}

/// One size row across all node counts.
#[derive(Debug, Clone)]
pub struct ScaleRow {
    pub size: u64,
    pub cells: Vec<ScaleCell>,
}

/// Sweep a hierarchical collective over `node_counts` × sizes (default
/// 1KB..1GB ×4), selector-chosen configuration per cell.
pub fn scaling<K: Into<ClusterKind>>(
    kind: K,
    node_counts: &[usize],
    sizes: Option<Vec<u64>>,
) -> Vec<ScaleRow> {
    let kind = kind.into();
    let sizes = sizes.unwrap_or_else(|| size_sweep(KB, GB, 4));
    let opts = HierRunOptions::default();
    sizes
        .into_iter()
        .map(|size| {
            let cells = node_counts
                .iter()
                .map(|&n| {
                    let cluster = ClusterTopology::mi300x(n);
                    // Round the nominal size up to a multiple of this
                    // cell's world size (a no-op for power-of-two node
                    // counts on the power-of-two sweeps).
                    let size = cluster.pad_size(size);
                    let (choice, ag_choice, r) = match kind {
                        ClusterKind::AllGather | ClusterKind::AllToAll => {
                            let choice = select_cluster(kind, &cluster, size);
                            let r = run_hier(kind.transport(), choice, &cluster, size, &opts);
                            (choice, None, r)
                        }
                        ClusterKind::ReduceScatter => {
                            let choice = select_cluster(kind, &cluster, size);
                            let r = run_hier_rs(choice, &cluster, size, &opts);
                            (choice, None, r)
                        }
                        ClusterKind::AllReduce => {
                            let (rs, ag) = select_allreduce(&cluster, size);
                            let r = run_hier_ar(rs, ag, &cluster, size, &opts);
                            (rs, Some(ag), r)
                        }
                    };
                    ScaleCell {
                        nodes: n,
                        choice,
                        ag_choice,
                        latency_ns: r.latency_ns,
                        inter_ns: r.inter_ns,
                    }
                })
                .collect();
            ScaleRow { size, cells }
        })
        .collect()
}

/// Render a scaling sweep as an ASCII table: per node count, the latency
/// in µs and the selector's choice.
pub fn render<K: Into<ClusterKind>>(kind: K, rows: &[ScaleRow]) -> String {
    let mut header = vec!["size".to_string()];
    if let Some(r0) = rows.first() {
        for c in &r0.cells {
            header.push(format!("{}n_us", c.nodes));
            header.push(format!("{}n_choice", c.nodes));
        }
    }
    let mut t = crate::util::table::Table::new(header);
    for r in rows {
        let mut cells = vec![fmt_size(r.size)];
        for c in &r.cells {
            cells.push(format!("{:.1}", c.latency_ns as f64 / 1e3));
            cells.push(c.choice_name());
        }
        t.row(cells);
    }
    format!("cluster scaling — {}\n{}", kind.into().name(), t.render())
}

/// CSV dump of a scaling sweep.
pub fn to_csv(rows: &[ScaleRow]) -> crate::util::csv::Csv {
    let mut header = vec!["size_bytes".to_string()];
    if let Some(r0) = rows.first() {
        for c in &r0.cells {
            header.push(format!("nodes{}_ns", c.nodes));
            header.push(format!("nodes{}_inter_ns", c.nodes));
            header.push(format!("nodes{}_choice", c.nodes));
        }
    }
    let mut csv = crate::util::csv::Csv::new(header);
    for r in rows {
        let mut cells = vec![r.size.to_string()];
        for c in &r.cells {
            cells.push(c.latency_ns.to_string());
            cells.push(c.inter_ns.to_string());
            cells.push(c.choice_name());
        }
        csv.row(cells);
    }
    csv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::CollectiveKind;
    use crate::util::bytes::MB;

    #[test]
    fn scaling_shape_and_monotonicity() {
        let rows = scaling(
            CollectiveKind::AllGather,
            &[1, 2],
            Some(vec![64 * KB, 4 * MB]),
        );
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.cells.len(), 2);
            assert!(r.cells.iter().all(|c| c.latency_ns > 0));
        }
        // Crossing nodes costs: 2-node latency exceeds 1-node at the same
        // size, and the single-node cell has no NIC component.
        let big = &rows[1];
        assert!(big.cells[1].latency_ns > big.cells[0].latency_ns);
        assert_eq!(big.cells[0].inter_ns, 0);
        assert!(big.cells[1].inter_ns > 0);
    }

    #[test]
    fn render_and_csv_include_choices() {
        let rows = scaling(CollectiveKind::AllToAll, &[1, 2], Some(vec![256 * KB]));
        let s = render(CollectiveKind::AllToAll, &rows);
        assert!(s.contains("alltoall") && s.contains("2n_us"), "{s}");
        let csv = to_csv(&rows).render();
        assert!(csv.contains("nodes2_ns"), "{csv}");
    }

    #[test]
    fn reduce_kinds_scale_and_compose() {
        let sizes = Some(vec![64 * KB, 4 * MB]);
        let rs = scaling(ClusterKind::ReduceScatter, &[1, 2], sizes.clone());
        let ar = scaling(ClusterKind::AllReduce, &[1, 2], sizes);
        for rows in [&rs, &ar] {
            for r in rows.iter() {
                assert!(r.cells.iter().all(|c| c.latency_ns > 0));
                assert_eq!(r.cells[0].inter_ns, 0);
                assert!(r.cells[1].inter_ns > 0);
            }
        }
        // AR = RS + AG per cell, so AR strictly dominates RS.
        for (rrow, arow) in rs.iter().zip(&ar) {
            for (rc, ac) in rrow.cells.iter().zip(&arow.cells) {
                assert!(ac.latency_ns > rc.latency_ns);
            }
        }
        // AR cells carry both phase choices in the composite label.
        let label = ar[0].cells[1].choice_name();
        assert!(label.contains('+'), "{label}");
        let s = render(ClusterKind::AllReduce, &ar);
        assert!(s.contains("allreduce"), "{s}");
    }
}
