//! Cluster scaling figures (beyond the paper): hierarchical DMA collective
//! latency across node counts (1 → 8) and sizes (1KB → 1GB) for the full
//! [`ClusterKind`] set — all-gather, all-to-all, reduce-scatter and
//! all-reduce — with the cluster-aware selector picking the configuration
//! per cell (for all-reduce: one choice per phase; multi-node all-reduce
//! cells run the chunk-granular [`InterSchedule::Overlapped`] schedule and
//! additionally report what the fusion saved over the barriered
//! composition). The single-node column reproduces the flat collective
//! (reduce-scatter: the flat DMA+CU split), so the table reads as "what
//! scale-out costs on top of the paper's numbers".

use crate::cluster::{
    overlap_report, run_hier, run_hier_ar, run_hier_rs, select_allreduce, select_cluster,
    ClusterChoice, ClusterKind, ClusterTopology, HierRunOptions, InterSchedule,
};
use crate::util::bytes::{fmt_size, size_sweep, GB, KB};

/// One (node count) cell of a scaling row.
#[derive(Debug, Clone)]
pub struct ScaleCell {
    pub nodes: usize,
    /// Selector choice (the reduce-scatter phase choice for all-reduce).
    pub choice: ClusterChoice,
    /// All-reduce only: the gather-phase choice.
    pub ag_choice: Option<ClusterChoice>,
    pub latency_ns: u64,
    pub inter_ns: u64,
    /// All-reduce cells on the overlapped schedule: latency the
    /// chunk-granular fusion shaved off the barriered composition
    /// (`None` elsewhere).
    pub overlap_saved_ns: Option<u64>,
}

impl ScaleCell {
    /// Figure-label name of the cell's configuration (`rs+ag` composite
    /// for all-reduce).
    pub fn choice_name(&self) -> String {
        match &self.ag_choice {
            Some(ag) => format!("{}+{}", self.choice.name(), ag.name()),
            None => self.choice.name(),
        }
    }
}

/// One size row across all node counts.
#[derive(Debug, Clone)]
pub struct ScaleRow {
    pub size: u64,
    pub cells: Vec<ScaleCell>,
}

/// Sweep a hierarchical collective over `node_counts` × sizes (default
/// 1KB..1GB ×4), selector-chosen configuration per cell.
pub fn scaling<K: Into<ClusterKind>>(
    kind: K,
    node_counts: &[usize],
    sizes: Option<Vec<u64>>,
) -> Vec<ScaleRow> {
    scaling_with_schedule(kind, node_counts, sizes, None)
}

/// [`scaling`] with the inter schedule optionally forced (`None` = the
/// selector's per-cell choice; the `dma-latte cluster --schedule` flag
/// maps here). Forcing [`InterSchedule::Overlapped`] on a non-all-reduce
/// kind runs its single leg with per-block eligibility (the schedule's
/// degenerate within-leg meaning).
pub fn scaling_with_schedule<K: Into<ClusterKind>>(
    kind: K,
    node_counts: &[usize],
    sizes: Option<Vec<u64>>,
    schedule: Option<InterSchedule>,
) -> Vec<ScaleRow> {
    let kind = kind.into();
    let sizes = sizes.unwrap_or_else(|| size_sweep(KB, GB, 4));
    let opts = HierRunOptions::default();
    let force = |mut c: ClusterChoice| {
        if let Some(s) = schedule {
            c.inter = s;
        }
        c
    };
    sizes
        .into_iter()
        .map(|size| {
            let cells = node_counts
                .iter()
                .map(|&n| {
                    let cluster = ClusterTopology::mi300x(n);
                    // Round the nominal size up to a multiple of this
                    // cell's world size (a no-op for power-of-two node
                    // counts on the power-of-two sweeps).
                    let size = cluster.pad_size(size);
                    let (choice, ag_choice, r, saved) = match kind {
                        ClusterKind::AllGather | ClusterKind::AllToAll => {
                            let choice = force(select_cluster(kind, &cluster, size));
                            let r = run_hier(kind.transport(), choice, &cluster, size, &opts);
                            (choice, None, r, None)
                        }
                        ClusterKind::ReduceScatter => {
                            let choice = force(select_cluster(kind, &cluster, size));
                            let r = run_hier_rs(choice, &cluster, size, &opts);
                            (choice, None, r, None)
                        }
                        ClusterKind::AllReduce => {
                            let (rs, ag) = select_allreduce(&cluster, size);
                            let (rs, ag) = (force(rs), force(ag));
                            if rs.inter == InterSchedule::Overlapped
                                || ag.inter == InterSchedule::Overlapped
                            {
                                let rep = overlap_report(rs, ag, &cluster, size, &opts);
                                (rs, Some(ag), rep.overlapped, Some(rep.saved_ns))
                            } else {
                                let r = run_hier_ar(rs, ag, &cluster, size, &opts);
                                (rs, Some(ag), r, None)
                            }
                        }
                    };
                    ScaleCell {
                        nodes: n,
                        choice,
                        ag_choice,
                        latency_ns: r.latency_ns,
                        inter_ns: r.inter_ns,
                        overlap_saved_ns: saved,
                    }
                })
                .collect();
            ScaleRow { size, cells }
        })
        .collect()
}

/// Render a scaling sweep as an ASCII table: per node count, the latency
/// in µs, the selector's choice, and — on overlapped all-reduce cells —
/// the latency saved vs the barriered composition.
pub fn render<K: Into<ClusterKind>>(kind: K, rows: &[ScaleRow]) -> String {
    let with_saved = rows
        .iter()
        .any(|r| r.cells.iter().any(|c| c.overlap_saved_ns.is_some()));
    let mut header = vec!["size".to_string()];
    if let Some(r0) = rows.first() {
        for c in &r0.cells {
            header.push(format!("{}n_us", c.nodes));
            header.push(format!("{}n_choice", c.nodes));
            if with_saved {
                header.push(format!("{}n_saved_us", c.nodes));
            }
        }
    }
    let mut t = crate::util::table::Table::new(header);
    for r in rows {
        let mut cells = vec![fmt_size(r.size)];
        for c in &r.cells {
            cells.push(format!("{:.1}", c.latency_ns as f64 / 1e3));
            cells.push(c.choice_name());
            if with_saved {
                cells.push(match c.overlap_saved_ns {
                    Some(s) => format!("{:.1}", s as f64 / 1e3),
                    None => "-".to_string(),
                });
            }
        }
        t.row(cells);
    }
    format!("cluster scaling — {}\n{}", kind.into().name(), t.render())
}

/// CSV dump of a scaling sweep. The overlap-savings column only appears
/// on sweeps where some cell ran the fused schedule (all-reduce),
/// mirroring [`render`] — other kinds keep their pre-overlap schema.
pub fn to_csv(rows: &[ScaleRow]) -> crate::util::csv::Csv {
    let with_saved = rows
        .iter()
        .any(|r| r.cells.iter().any(|c| c.overlap_saved_ns.is_some()));
    let mut header = vec!["size_bytes".to_string()];
    if let Some(r0) = rows.first() {
        for c in &r0.cells {
            header.push(format!("nodes{}_ns", c.nodes));
            header.push(format!("nodes{}_inter_ns", c.nodes));
            header.push(format!("nodes{}_choice", c.nodes));
            if with_saved {
                header.push(format!("nodes{}_overlap_saved_ns", c.nodes));
            }
        }
    }
    let mut csv = crate::util::csv::Csv::new(header);
    for r in rows {
        let mut cells = vec![r.size.to_string()];
        for c in &r.cells {
            cells.push(c.latency_ns.to_string());
            cells.push(c.inter_ns.to_string());
            cells.push(c.choice_name());
            if with_saved {
                cells.push(c.overlap_saved_ns.unwrap_or(0).to_string());
            }
        }
        csv.row(cells);
    }
    csv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::CollectiveKind;
    use crate::util::bytes::MB;

    #[test]
    fn scaling_shape_and_monotonicity() {
        let rows = scaling(
            CollectiveKind::AllGather,
            &[1, 2],
            Some(vec![64 * KB, 4 * MB]),
        );
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.cells.len(), 2);
            assert!(r.cells.iter().all(|c| c.latency_ns > 0));
        }
        // Crossing nodes costs: 2-node latency exceeds 1-node at the same
        // size, and the single-node cell has no NIC component.
        let big = &rows[1];
        assert!(big.cells[1].latency_ns > big.cells[0].latency_ns);
        assert_eq!(big.cells[0].inter_ns, 0);
        assert!(big.cells[1].inter_ns > 0);
    }

    #[test]
    fn render_and_csv_include_choices() {
        let rows = scaling(CollectiveKind::AllToAll, &[1, 2], Some(vec![256 * KB]));
        let s = render(CollectiveKind::AllToAll, &rows);
        assert!(s.contains("alltoall") && s.contains("2n_us"), "{s}");
        let csv = to_csv(&rows).render();
        assert!(csv.contains("nodes2_ns"), "{csv}");
    }

    #[test]
    fn reduce_kinds_scale_and_compose() {
        let sizes = Some(vec![64 * KB, 4 * MB]);
        let rs = scaling(ClusterKind::ReduceScatter, &[1, 2], sizes.clone());
        let ar = scaling(ClusterKind::AllReduce, &[1, 2], sizes);
        for rows in [&rs, &ar] {
            for r in rows.iter() {
                assert!(r.cells.iter().all(|c| c.latency_ns > 0));
                assert_eq!(r.cells[0].inter_ns, 0);
                assert!(r.cells[1].inter_ns > 0);
            }
        }
        // AR contains a full RS phase (fused or not), so AR strictly
        // dominates RS per cell.
        for (rrow, arow) in rs.iter().zip(&ar) {
            for (rc, ac) in rrow.cells.iter().zip(&arow.cells) {
                assert!(ac.latency_ns > rc.latency_ns);
            }
        }
        // AR cells carry both phase choices in the composite label; the
        // multi-node cells run the fused schedule and report savings.
        let cell = &ar[0].cells[1];
        let label = cell.choice_name();
        assert!(label.contains('+') && label.contains("ovl"), "{label}");
        assert!(cell.overlap_saved_ns.is_some());
        assert!(ar[0].cells[0].overlap_saved_ns.is_none(), "1-node: no fusion");
        let s = render(ClusterKind::AllReduce, &ar);
        assert!(s.contains("allreduce") && s.contains("2n_saved_us"), "{s}");
        let csv = to_csv(&ar).render();
        assert!(csv.contains("nodes2_overlap_saved_ns"), "{csv}");
    }

    /// Acceptance (PR 4): on every figure-sweep cell the overlapped AR is
    /// at least as fast as BOTH barriered compositions (sequential and
    /// pipelined), i.e. the fusion never loses.
    #[test]
    fn overlapped_cells_never_lose_to_barriered_schedules() {
        let sizes = Some(vec![64 * KB, MB, 16 * MB]);
        let nodes = [1usize, 2, 4];
        let ovl = scaling_with_schedule(
            ClusterKind::AllReduce,
            &nodes,
            sizes.clone(),
            Some(InterSchedule::Overlapped),
        );
        let seq = scaling_with_schedule(
            ClusterKind::AllReduce,
            &nodes,
            sizes.clone(),
            Some(InterSchedule::Sequential),
        );
        let pipe = scaling_with_schedule(
            ClusterKind::AllReduce,
            &nodes,
            sizes,
            Some(InterSchedule::Pipelined),
        );
        for ((orow, srow), prow) in ovl.iter().zip(&seq).zip(&pipe) {
            for ((oc, sc), pc) in orow.cells.iter().zip(&srow.cells).zip(&prow.cells) {
                let best = sc.latency_ns.min(pc.latency_ns);
                assert!(
                    oc.latency_ns <= best,
                    "size {} nodes {}: ovl {} vs best barriered {best}",
                    orow.size,
                    oc.nodes,
                    oc.latency_ns
                );
                assert_eq!(oc.overlap_saved_ns.unwrap(), pc.latency_ns - oc.latency_ns);
            }
        }
    }
}
