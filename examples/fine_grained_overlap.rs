//! Fine-grained compute/communication overlap (paper §2.3 and [29]):
//! tiles of a producer GEMM are communicated as soon as they are ready,
//! instead of waiting for the whole kernel — expressed with *prelaunched*
//! poll-gated DMA chains (§4.5), one per tile.
//!
//! The GEMM is modeled as a host program that completes tiles at a fixed
//! cadence and bumps a progress signal; each tile's broadcast to two
//! consumer GPUs was pre-scheduled with a `Poll(progress >= tile+1)` gate,
//! so no launch work sits on the critical path when a tile finishes.
//!
//! Run: cargo run --release --example fine_grained_overlap

use dma_latte::sim::command::{Addr, AtomicOp, Command, PollCond};
use dma_latte::sim::host::{ApiKind, HostOp};
use dma_latte::sim::topology::NodeId;
use dma_latte::sim::{EngineId, Sim, SimConfig};
use dma_latte::util::bytes::{fmt_ns, KB};

const TILES: u64 = 16;
const TILE_BYTES: u64 = 256 * KB;
const TILE_COMPUTE_NS: u64 = 18_000; // producer cadence per tile

/// Build and run the pipeline; `prelaunch` toggles poll-gated chains vs
/// launching each tile's transfer after it completes.
fn run(prelaunch: bool) -> (u64, u64) {
    let mut sim = Sim::new(SimConfig::mi300x().functional());
    let progress = sim.alloc_signal(0);
    let done = sim.alloc_signal(0);
    let engine = EngineId { gpu: 0, idx: 0 };

    // Tile t lives at t*TILE_BYTES on gpu0, mirrored to gpu1 & gpu2.
    for t in 0..TILES {
        sim.memory.poke(
            NodeId::Gpu(0),
            t * TILE_BYTES,
            &vec![(t as u8) + 1; TILE_BYTES as usize],
        );
    }
    let tile_cmds = |t: u64| Command::Bcst {
        src: Addr::new(NodeId::Gpu(0), t * TILE_BYTES),
        dst0: Addr::new(NodeId::Gpu(1), t * TILE_BYTES),
        dst1: Addr::new(NodeId::Gpu(2), t * TILE_BYTES),
        len: TILE_BYTES,
    };

    let mut script = Vec::new();
    if prelaunch {
        // Pre-schedule ONE b2b chain: each tile's transfer gated on the
        // producer's progress signal reaching it.
        let mut cmds = Vec::new();
        for t in 0..TILES {
            cmds.push(Command::Poll {
                signal: progress,
                cond: PollCond::Gte((t + 1) as i64),
            });
            cmds.push(tile_cmds(t));
        }
        cmds.push(Command::Atomic {
            signal: done,
            op: AtomicOp::Add(1),
        });
        script.push(HostOp::CreateCommands {
            engine,
            cmds,
            api: ApiKind::RawBatched,
        });
        script.push(HostOp::RingDoorbell { engine });
        script.push(HostOp::Delay { ns: 10_000 });
    }
    script.push(HostOp::Mark { name: "gemm_start" });
    for t in 0..TILES {
        // Producer computes tile t…
        script.push(HostOp::Delay {
            ns: TILE_COMPUTE_NS,
        });
        if prelaunch {
            // …and only flips the progress signal (off critical path).
            script.push(HostOp::SetSignal {
                signal: progress,
                value: (t + 1) as i64,
            });
        } else {
            // …then must create + launch the transfer on the spot.
            let mut cmds = vec![tile_cmds(t)];
            if t == TILES - 1 {
                cmds.push(Command::Atomic {
                    signal: done,
                    op: AtomicOp::Add(1),
                });
            }
            script.push(HostOp::CreateCommands {
                engine,
                cmds,
                api: ApiKind::Raw,
            });
            script.push(HostOp::RingDoorbell { engine });
        }
    }
    script.push(HostOp::WaitSignal {
        signal: done,
        at_least: 1,
    });
    script.push(HostOp::Mark { name: "all_done" });
    sim.add_host(script, 0);
    let out = sim.run();
    assert!(out.deadlocked.is_empty());
    // Verify all tiles arrived at both consumers.
    for t in 0..TILES {
        for g in [1u8, 2] {
            let got = sim.memory.peek(NodeId::Gpu(g), t * TILE_BYTES, TILE_BYTES);
            assert!(got.iter().all(|&b| b == (t as u8) + 1), "tile {t} gpu{g}");
        }
    }
    let h = sim.host(dma_latte::sim::HostId(0));
    let total = h.mark("all_done").unwrap() - h.mark("gemm_start").unwrap();
    let compute = TILES * TILE_COMPUTE_NS;
    (total, total - compute)
}

fn main() {
    println!("Fine-grained GEMM-tile broadcast: {TILES} tiles × 256KiB");
    println!("producer compute: {} total\n", fmt_ns((TILES * TILE_COMPUTE_NS) as f64));
    let (t_direct, exp_direct) = run(false);
    let (t_pre, exp_pre) = run(true);
    println!("launch-per-tile : total {:>10}  exposed comm {:>10}", fmt_ns(t_direct as f64), fmt_ns(exp_direct as f64));
    println!("prelaunched     : total {:>10}  exposed comm {:>10}", fmt_ns(t_pre as f64), fmt_ns(exp_pre as f64));
    println!(
        "\nprelaunch hides {:.0}% of the exposed communication time",
        (1.0 - exp_pre as f64 / exp_direct as f64) * 100.0
    );
    println!("(per-tile launch overheads are off the producer's critical path — §4.5)");
}
