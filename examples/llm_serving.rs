//! END-TO-END DRIVER: serve real batched requests through the full stack.
//!
//! All three layers compose here, with Python nowhere on the request path:
//!   L1  Pallas paged-attention kernel  ┐ lowered once to HLO text
//!   L2  JAX transformer (~55M params)  ┘ (`make artifacts`)
//!   L3  Rust coordinator: router → scheduler → paged-KV fetch through the
//!       DMA simulator → PJRT-executed prefill/decode
//!
//! Reports wall-clock TTFT / throughput for batched requests plus the
//! MI300X-projected serving numbers. Recorded in EXPERIMENTS.md §E2E.
//!
//! Usage: cargo run --release --example llm_serving [num_requests] [new_tokens]

use std::time::Instant;

use dma_latte::coordinator::request::Request;
use dma_latte::coordinator::router::{RoutePolicy, Router};
use dma_latte::coordinator::server::{Server, ServerConfig};
use dma_latte::kvcache::fetch::FetchImpl;
use dma_latte::kvcache::BlockLayout;
use dma_latte::models::zoo::QWEN25_0_5B;
use dma_latte::runtime::PjrtBackend;
use dma_latte::util::stats;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(24);
    let new_tokens: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("meta.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    println!("== DMA-Latte end-to-end serving ==");
    println!("model: compiled tiny transformer (~55M params) via JAX→HLO→PJRT");
    println!("requests: {n_requests} × (prompt 128, generate {new_tokens})\n");

    // Router in front (vllm-router style); single PJRT replica behind it.
    let mut router = Router::new(1, RoutePolicy::LeastOutstanding);

    let t_load = Instant::now();
    let server = Server::start(
        ServerConfig {
            // KV geometry of the compiled model (layer count etc. come from
            // the artifact metadata inside the backend; the serving layout
            // uses the paper's models for the simulated figures, and the
            // compiled model's real geometry here).
            layout: BlockLayout::new(&QWEN25_0_5B, 16),
            fetch: FetchImpl::DmaB2b,
            gpu_blocks: 1 << 16,
            cpu_blocks: 1 << 18,
            max_batch: 4, // the artifact's compiled decode batch
        },
        move || {
            PjrtBackend::load(
                std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            )
            .expect("backend load")
        },
    );

    // Submit batched requests.
    let t0 = Instant::now();
    for i in 0..n_requests {
        let replica = router.route(i, Some(i % 4));
        assert_eq!(replica, 0);
        let prompt: Vec<u32> = (0..128u32).map(|t| (i as u32 * 131 + t * 7) % 16000).collect();
        server.submit(Request::new(i, 128, new_tokens, 0), prompt);
    }

    // Collect completions.
    let mut ttfts = Vec::new();
    let mut totals = Vec::new();
    for _ in 0..n_requests {
        let c = server.next_completion().expect("completion");
        router.complete(c.id);
        ttfts.push(c.ttft.as_secs_f64() * 1e3);
        totals.push(c.total.as_secs_f64() * 1e3);
        assert_eq!(c.tokens.len() as u64, new_tokens);
    }
    let wall = t0.elapsed();
    let metrics = server.shutdown();

    println!("backend load+compile: {:.2}s", t_load.elapsed().as_secs_f64());
    println!("wall time: {:.2}s for {} requests", wall.as_secs_f64(), n_requests);
    println!(
        "TTFT   : mean {:.1}ms  p50 {:.1}ms  p99 {:.1}ms",
        stats::mean(&ttfts),
        stats::median(&ttfts),
        stats::percentile(&ttfts, 99.0)
    );
    println!(
        "latency: mean {:.1}ms per request ({} tokens)",
        stats::mean(&totals),
        new_tokens
    );
    println!(
        "throughput: {:.1} tok/s wall-clock  ({} tokens total)",
        metrics.tokens_out as f64 / wall.as_secs_f64(),
        metrics.tokens_out
    );
    println!(
        "KV offload: {} hits, {} misses, {:.1} MiB fetched via b2b DMA",
        metrics.cache_hits,
        metrics.cache_misses,
        metrics.fetch_bytes as f64 / (1024.0 * 1024.0)
    );
    println!("\nAll layers composed: JAX/Pallas-compiled HLO executed from the");
    println!("Rust coordinator with paged-KV CPU offload — no Python at runtime.");
}
