//! Quickstart: the DMA-Latte public API in one minute.
//!
//! Runs an all-gather with the auto-selected DMA variant, verifies its
//! result functionally, compares it against the RCCL baseline model, and
//! measures a batched KV fetch — the paper's two contributions in ~60
//! lines. Run with `cargo run --release --example quickstart`.

use dma_latte::collectives::{
    run_collective, select_variant, CollectiveKind, RunOptions,
};
use dma_latte::kvcache::fetch::{run_fetch, FetchImpl};
use dma_latte::rccl::RcclModel;
use dma_latte::sim::topology::NodeId;
use dma_latte::sim::{Addr, Sim, SimConfig};
use dma_latte::util::bytes::{fmt_ns, fmt_size, KB, MB};

fn main() {
    // 1) Collectives: auto-selected DMA variant vs the CU-based baseline.
    println!("== DMA collectives (8× MI300X, simulated) ==");
    let rccl = RcclModel::default();
    let opts = RunOptions {
        sim: SimConfig::mi300x(),
        verify: true, // move real bytes + check AG = concatenation
    };
    for size in [64 * KB, 2 * MB, 64 * MB] {
        let kind = CollectiveKind::AllGather;
        let variant = select_variant(kind, size);
        let r = run_collective(kind, variant, size, &opts);
        let rccl_ns = rccl.latency_ns(kind, &opts.sim.topology, size);
        println!(
            "allgather {:>5}: {:<15} {:>10}  (RCCL {:>10})  speedup {:.2}x  verified={}",
            fmt_size(size),
            variant.name(),
            fmt_ns(r.latency_ns as f64),
            fmt_ns(rccl_ns),
            rccl_ns / r.latency_ns as f64,
            r.verified.unwrap(),
        );
    }

    // 2) KV fetch: per-copy hipMemcpyAsync vs batched b2b (the paper §5.3).
    println!("\n== KV fetch: 256 × 192KiB blocks (Qwen2.5-0.5B, 4096 tokens) ==");
    let copies: Vec<_> = (0..256u64)
        .map(|i| {
            (
                Addr::new(NodeId::Cpu, i * 196_608),
                Addr::new(NodeId::Gpu(0), i * 196_608),
                196_608,
            )
        })
        .collect();
    for imp in [FetchImpl::DmaBaseline, FetchImpl::DmaB2b, FetchImpl::Kernel] {
        let mut sim = Sim::new(SimConfig::mi300x());
        let out = run_fetch(&mut sim, imp, &copies);
        println!(
            "{:<14} host {:>10}  total {:>10}  engines {:>2}  api calls {}",
            imp.name(),
            fmt_ns(out.host_ns as f64),
            fmt_ns(out.total_ns as f64),
            out.engines_used,
            out.api_calls,
        );
    }
    println!("\nSee `cargo bench` for the full paper-figure reproductions.");
}
