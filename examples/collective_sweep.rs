//! Collective sweep: regenerates the Figs. 1/13/14 data interactively.
//!
//! Usage: `cargo run --release --example collective_sweep [allgather|alltoall] [max_size]`
//! e.g. `cargo run --release --example collective_sweep alltoall 64M`

use dma_latte::collectives::CollectiveKind;
use dma_latte::figures::collectives as fig;
use dma_latte::util::bytes::{parse_size, size_sweep, GB, KB, MB};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let kind = match args.first().map(String::as_str) {
        Some("alltoall") => CollectiveKind::AllToAll,
        _ => CollectiveKind::AllGather,
    };
    let max = args
        .get(1)
        .map(|s| parse_size(s).expect("bad size"))
        .unwrap_or(4 * GB);
    let sizes = size_sweep(KB, max, 2);
    eprintln!("sweeping {} over {} sizes…", kind.name(), sizes.len());
    let rows = fig::sweep(kind, Some(sizes));
    print!("{}", fig::render(kind, &rows));

    println!("\nBest implementation per size range (Tables 2/3):");
    for (lo, hi, v) in fig::best_table(&rows) {
        println!(
            "  {:>6} ..= {:>6}  ->  {}",
            dma_latte::util::bytes::fmt_size(lo),
            dma_latte::util::bytes::fmt_size(hi),
            v.name()
        );
    }
    let below = 32 * MB;
    println!(
        "\ngeomean best-DMA speedup vs RCCL (<32M): {:.2}x",
        fig::geomean_best(&rows, below)
    );
}
