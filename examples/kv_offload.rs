//! KV offload scenario: context caching for long-context inference
//! (paper §2.1.2 / Fig. 3). For each model in the zoo, measures fetching a
//! 4096-token cached context from CPU memory with the three fetch
//! implementations, plus the resulting single-request TTFT.

use dma_latte::coordinator::{ServeConfig, VirtualEngine};
use dma_latte::kvcache::fetch::{run_fetch, FetchImpl};
use dma_latte::kvcache::BlockLayout;
use dma_latte::models::ALL_MODELS;
use dma_latte::sim::{Sim, SimConfig};
use dma_latte::util::bytes::{fmt_ns, fmt_size};
use dma_latte::util::table::Table;

fn main() {
    let prompt = 4096u64;
    let mut t = Table::new(vec![
        "model",
        "block",
        "blocks",
        "base_fetch",
        "b2b_fetch",
        "kern_fetch",
        "TTFT base",
        "TTFT b2b",
    ]);
    for &m in ALL_MODELS {
        let layout = BlockLayout::new(m, 16);
        let blocks = layout.blocks_for(prompt);
        let copies: Vec<_> = (0..blocks)
            .map(|i| {
                (
                    layout.cpu_block_addr(i),
                    layout.gpu_block_addr(0, i),
                    layout.block_bytes,
                )
            })
            .collect();
        let f = |imp| {
            let mut sim = Sim::new(SimConfig::mi300x());
            run_fetch(&mut sim, imp, &copies).total_ns
        };
        let base = f(FetchImpl::DmaBaseline);
        let b2b = f(FetchImpl::DmaB2b);
        let kern = f(FetchImpl::Kernel);
        let (_, ttft_base) =
            VirtualEngine::measure_ttft(&ServeConfig::new(m, FetchImpl::DmaBaseline), prompt);
        let (_, ttft_b2b) =
            VirtualEngine::measure_ttft(&ServeConfig::new(m, FetchImpl::DmaB2b), prompt);
        t.row(vec![
            m.name.to_string(),
            fmt_size(layout.block_bytes),
            blocks.to_string(),
            fmt_ns(base as f64),
            fmt_ns(b2b as f64),
            fmt_ns(kern as f64),
            fmt_ns(ttft_base as f64),
            fmt_ns(ttft_b2b as f64),
        ]);
    }
    t.print();
    println!("\nb2b batching pays off most where blocks are small (small models):");
    println!("fewer API calls + single sync per chain (paper §5.3.3).");
}
