"""AOT artifact checks (fast: validates existing artifacts; the expensive
lowering itself runs under `make artifacts` and the Rust runtime_load test
executes the artifacts end to end)."""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "meta.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def load(name):
    with open(os.path.join(ART, name)) as f:
        return json.load(f)


class TestMeta:
    def test_config_matches_model(self):
        from compile.model import CONFIG, num_params, param_manifest

        meta = load("meta.json")
        assert meta["config"] == CONFIG
        man = meta["param_manifest"]
        assert len(man) == len(param_manifest())
        total = sum(int(np.prod(e["shape"])) for e in man)
        assert total == num_params()

    def test_artifact_files_exist_and_are_hlo(self):
        meta = load("meta.json")
        for art in meta["artifacts"].values():
            p = os.path.join(ART, art["file"])
            assert os.path.exists(p), p
            head = open(p).read(4096)
            assert "HloModule" in head, f"{p} is not HLO text"
            assert "ENTRY" in open(p).read(), p

    def test_decode_signature(self):
        meta = load("meta.json")
        d = meta["artifacts"]["decode_step"]
        assert d["extra_args"][0].startswith("token[B]")
        assert len(d["outputs"]) == 2


class TestGolden:
    def test_checksums_finite_and_shaped(self):
        g = load("golden.json")
        from compile.model import CONFIG

        logits = g["decode_step"]["logits"]
        assert logits["shape"] == [CONFIG["batch"], CONFIG["vocab"]]
        assert np.isfinite(logits["abs_sum"])
        assert len(logits["first8"]) == 8
        pre = g["prefill"]["logits"]
        assert pre["shape"] == [1, CONFIG["vocab"]]

    def test_param_probe_matches_regeneration(self):
        """The probe values regenerate from the manifest (the same check the
        Rust side performs, closing the cross-language loop)."""
        from compile.model import CONFIG, counter_uniform, param_manifest

        g = load("golden.json")
        man = param_manifest()
        seed = CONFIG["param_seed"]
        name, shape, scale, offset = man[0]
        assert name == "embed"
        got = counter_uniform(seed, offset, 4) * np.float32(scale)
        np.testing.assert_allclose(got, g["param_probe"]["embed_first4"], rtol=1e-6)
        name, shape, scale, offset = man[-1]
        assert name == "unembed"
        got = counter_uniform(seed, offset, 4) * np.float32(scale)
        np.testing.assert_allclose(got, g["param_probe"]["unembed_first4"], rtol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
