"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (heads, head_dim, block counts, ragged context
lengths) — the CORE correctness signal for the compute layer.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.kv_gather import kv_gather
from compile.kernels.paged_attention import paged_attention, vmem_footprint_bytes
from compile.kernels.ref import ref_kv_gather, ref_paged_attention


def _mk_case(rng, b, h, kvh, d, nb, bs, mb, ctx):
    q = rng.standard_normal((b, h, d)).astype(np.float32)
    pool = (rng.standard_normal((nb, bs, 2, kvh, d)) * 0.3).astype(np.float32)
    bt = np.stack([rng.permutation(nb)[:mb].astype(np.int32) for _ in range(b)])
    lens = np.asarray(ctx, dtype=np.int32)
    k_new = rng.standard_normal((b, kvh, d)).astype(np.float32)
    v_new = rng.standard_normal((b, kvh, d)).astype(np.float32)
    return q, pool, bt, lens, k_new, v_new


class TestPagedAttention:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        b=st.integers(1, 3),
        kvh=st.integers(1, 3),
        groups=st.integers(1, 4),
        d=st.sampled_from([4, 8, 16]),
        bs=st.sampled_from([4, 8, 16]),
        mb=st.integers(1, 4),
    )
    def test_matches_ref_across_shapes(self, seed, b, kvh, groups, d, bs, mb):
        rng = np.random.default_rng(seed)
        h = kvh * groups
        nb = mb + 3
        ctx = rng.integers(0, mb * bs + 1, size=b)
        q, pool, bt, lens, k_new, v_new = _mk_case(rng, b, h, kvh, d, nb, bs, mb, ctx)
        got = paged_attention(
            jnp.asarray(q), jnp.asarray(pool), jnp.asarray(bt),
            jnp.asarray(lens), jnp.asarray(k_new), jnp.asarray(v_new))
        for i in range(b):
            want = ref_paged_attention(
                jnp.asarray(q[i]), jnp.asarray(pool), jnp.asarray(bt[i]),
                int(lens[i]), jnp.asarray(k_new[i]), jnp.asarray(v_new[i]))
            np.testing.assert_allclose(got[i], want, rtol=2e-5, atol=2e-5)

    def test_zero_context_attends_only_to_current(self):
        rng = np.random.default_rng(0)
        q, pool, bt, lens, k_new, v_new = _mk_case(rng, 1, 2, 2, 8, 4, 4, 2, [0])
        got = paged_attention(
            jnp.asarray(q), jnp.asarray(pool), jnp.asarray(bt),
            jnp.asarray(lens), jnp.asarray(k_new), jnp.asarray(v_new))
        # With no cached context, output == v_new per (GQA-expanded) head.
        want = np.repeat(v_new[0], 1, axis=0)
        np.testing.assert_allclose(got[0], want, rtol=1e-5, atol=1e-5)

    def test_full_context(self):
        rng = np.random.default_rng(1)
        b, h, kvh, d, nb, bs, mb = 2, 4, 2, 8, 6, 4, 3
        ctx = [mb * bs] * b  # fully filled
        q, pool, bt, lens, k_new, v_new = _mk_case(rng, b, h, kvh, d, nb, bs, mb, ctx)
        got = paged_attention(
            jnp.asarray(q), jnp.asarray(pool), jnp.asarray(bt),
            jnp.asarray(lens), jnp.asarray(k_new), jnp.asarray(v_new))
        for i in range(b):
            want = ref_paged_attention(
                jnp.asarray(q[i]), jnp.asarray(pool), jnp.asarray(bt[i]),
                int(lens[i]), jnp.asarray(k_new[i]), jnp.asarray(v_new[i]))
            np.testing.assert_allclose(got[i], want, rtol=2e-5, atol=2e-5)

    def test_outputs_finite(self):
        rng = np.random.default_rng(2)
        q, pool, bt, lens, k_new, v_new = _mk_case(rng, 3, 6, 2, 16, 8, 8, 4, [5, 17, 32])
        got = paged_attention(
            jnp.asarray(q), jnp.asarray(pool), jnp.asarray(bt),
            jnp.asarray(lens), jnp.asarray(k_new), jnp.asarray(v_new))
        assert np.isfinite(np.asarray(got)).all()

    def test_vmem_footprint_estimate(self):
        # The production-config footprint must fit a 16 MiB VMEM budget
        # (DESIGN.md §Perf L1).
        fp = vmem_footprint_bytes((128, 16, 2, 2, 64), h=10, d=64, mb=32)
        assert fp < 16 * 1024 * 1024, fp


class TestKvGather:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        nb=st.integers(2, 32),
        e=st.sampled_from([8, 64, 256]),
    )
    def test_matches_ref(self, seed, nb, e):
        rng = np.random.default_rng(seed)
        k = rng.integers(1, nb + 1)
        pool = rng.standard_normal((nb, e)).astype(np.float32)
        idx = rng.permutation(nb)[:k].astype(np.int32)
        got = kv_gather(jnp.asarray(pool), jnp.asarray(idx))
        want = ref_kv_gather(jnp.asarray(pool), jnp.asarray(idx))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_repeated_indices(self):
        pool = np.arange(12, dtype=np.float32).reshape(3, 4)
        idx = np.asarray([1, 1, 0], dtype=np.int32)
        got = np.asarray(kv_gather(jnp.asarray(pool), jnp.asarray(idx)))
        np.testing.assert_array_equal(got[0], got[1])
        np.testing.assert_array_equal(got[2], pool[0])

    def test_identity_permutation(self):
        pool = np.random.default_rng(3).standard_normal((8, 16)).astype(np.float32)
        idx = np.arange(8, dtype=np.int32)
        got = np.asarray(kv_gather(jnp.asarray(pool), jnp.asarray(idx)))
        np.testing.assert_array_equal(got, pool)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
