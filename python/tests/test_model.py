"""L2 model checks: shapes, determinism, decode-vs-prefill consistency."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.model import (
    CONFIG,
    counter_uniform,
    decode_step,
    init_params,
    num_params,
    param_manifest,
    prefill,
)

SMALL = {
    **CONFIG,
    "vocab": 128,
    "d_model": 32,
    "layers": 2,
    "heads": 4,
    "kv_heads": 2,
    "head_dim": 8,
    "ffn": 64,
    "block_size": 4,
    "max_blocks": 4,
    "num_blocks": 8,
    "batch": 2,
    "prefill_len": 8,
}


@pytest.fixture(scope="module")
def small_params():
    return init_params(SMALL)


class TestParams:
    def test_full_model_is_about_55m(self):
        n = num_params(CONFIG)
        assert 40e6 < n < 80e6, n

    def test_manifest_offsets_monotone(self):
        m = param_manifest(SMALL)
        offs = [e[3] for e in m]
        assert offs == sorted(offs)
        # offsets are dense: each offset = previous + numel
        for i in range(1, len(m)):
            prev = m[i - 1]
            assert m[i][3] == prev[3] + int(np.prod(prev[1]))

    def test_counter_uniform_deterministic_and_bounded(self):
        a = counter_uniform(42, 100, 1000)
        b = counter_uniform(42, 100, 1000)
        np.testing.assert_array_equal(a, b)
        assert (np.abs(a) < 1.0).all()
        assert abs(a.mean()) < 0.1  # roughly centered

    def test_norm_weights_are_ones(self, small_params):
        m = param_manifest(SMALL)
        for (name, _, scale, _), p in zip(m, small_params):
            if scale == 0.0:
                assert np.all(np.asarray(p) == 1.0), name


class TestPrefill:
    def test_shapes(self, small_params):
        t = SMALL["prefill_len"]
        tokens = jnp.arange(t, dtype=jnp.int32)[None, :] % SMALL["vocab"]
        logits, kv = prefill(small_params, tokens, SMALL)
        assert logits.shape == (1, SMALL["vocab"])
        assert kv.shape == (t, SMALL["layers"], 2, SMALL["kv_heads"], SMALL["head_dim"])
        assert np.isfinite(np.asarray(logits)).all()

    def test_deterministic(self, small_params):
        tokens = jnp.ones((1, SMALL["prefill_len"]), dtype=jnp.int32)
        a, _ = prefill(small_params, tokens, SMALL)
        b, _ = prefill(small_params, tokens, SMALL)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestDecode:
    def test_shapes(self, small_params):
        cfg = SMALL
        b, nb, bs = cfg["batch"], cfg["num_blocks"], cfg["block_size"]
        layers, kvh, hd = cfg["layers"], cfg["kv_heads"], cfg["head_dim"]
        token = jnp.asarray([1, 2], dtype=jnp.int32)
        pos = jnp.asarray([4, 7], dtype=jnp.int32)
        pool = jnp.zeros((nb, bs, layers, 2, kvh, hd), dtype=jnp.float32)
        bt = jnp.asarray(
            np.stack([np.arange(cfg["max_blocks"], dtype=np.int32)] * b))
        logits, new_kv = decode_step(small_params, token, pos, pool, bt, cfg)
        assert logits.shape == (b, cfg["vocab"])
        assert new_kv.shape == (b, layers, 2, kvh, hd)
        assert np.isfinite(np.asarray(logits)).all()

    def test_decode_consistent_with_prefill(self, small_params):
        """Prefill T tokens; decoding token T with the prefix KV paged into
        the pool must give the same logits as prefilling T+1 tokens."""
        cfg = SMALL
        t = cfg["prefill_len"] - 1
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg["vocab"], size=t + 1).astype(np.int32)

        # Oracle: prefill all T+1 tokens.
        full_logits, _ = prefill(small_params, jnp.asarray(toks)[None, :], cfg)

        # Prefill first T, page KV into the pool, decode token T.
        _, kv = prefill(small_params, jnp.asarray(toks[:t])[None, :], cfg)
        nb, bs = cfg["num_blocks"], cfg["block_size"]
        layers, kvh, hd = cfg["layers"], cfg["kv_heads"], cfg["head_dim"]
        pool = np.zeros((nb, bs, layers, 2, kvh, hd), dtype=np.float32)
        kvn = np.asarray(kv)  # [T, L, 2, KVH, D]
        mb = cfg["max_blocks"]
        table = np.arange(mb, dtype=np.int32)  # identity mapping
        for i in range(t):
            pool[table[i // bs], i % bs] = kvn[i]
        b = cfg["batch"]
        token = jnp.asarray([toks[t]] * b, dtype=jnp.int32)
        pos = jnp.asarray([t] * b, dtype=jnp.int32)
        bts = jnp.asarray(np.stack([table] * b))
        logits, _ = decode_step(small_params, token, pos, jnp.asarray(pool), bts, cfg)
        np.testing.assert_allclose(
            np.asarray(logits[0]), np.asarray(full_logits[0]), rtol=2e-4, atol=2e-4)

    def test_block_table_permutation_invariance(self, small_params):
        """Physical block placement must not change the result."""
        cfg = SMALL
        b = cfg["batch"]
        nb, bs = cfg["num_blocks"], cfg["block_size"]
        layers, kvh, hd = cfg["layers"], cfg["kv_heads"], cfg["head_dim"]
        rng = np.random.default_rng(5)
        kv_rows = (rng.standard_normal((8, layers, 2, kvh, hd)) * 0.3).astype(np.float32)

        def build(table):
            pool = np.zeros((nb, bs, layers, 2, kvh, hd), dtype=np.float32)
            for i in range(8):
                pool[table[i // bs], i % bs] = kv_rows[i]
            return pool

        t1 = np.asarray([0, 1, 2, 3], dtype=np.int32)
        t2 = np.asarray([5, 2, 7, 0], dtype=np.int32)
        token = jnp.asarray([3] * b, dtype=jnp.int32)
        pos = jnp.asarray([8] * b, dtype=jnp.int32)
        l1, _ = decode_step(small_params, token, pos, jnp.asarray(build(t1)),
                            jnp.asarray(np.stack([t1] * b)), cfg)
        l2, _ = decode_step(small_params, token, pos, jnp.asarray(build(t2)),
                            jnp.asarray(np.stack([t2] * b)), cfg)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
