"""AOT lowering: JAX (L2 + L1) → HLO text artifacts for the Rust runtime.

HLO *text* is the interchange format, NOT `.serialize()` — jax ≥ 0.5 emits
protos with 64-bit instruction ids that the xla crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md and
gen_hlo.py there).

Artifacts (under --out-dir, default ../artifacts):
  decode_step.hlo.txt  — batched paged-attention decode step
  prefill.hlo.txt      — single-sequence prefill
  kv_gather.hlo.txt    — Pallas KV block gather (kernel-fetch analogue)
  meta.json            — config, param manifest, artifact arg orders
  golden.json          — seeded test vectors (inputs → output checksums)
                         for the Rust runtime_load integration test
"""

import argparse
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.kv_gather import kv_gather
from .model import CONFIG, decode_step, init_params, num_params, param_manifest, prefill


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def example_inputs(cfg=CONFIG, seed=7):
    """Deterministic example inputs for golden vectors."""
    rng = np.random.default_rng(seed)
    b, mb, nb = cfg["batch"], cfg["max_blocks"], cfg["num_blocks"]
    bs, layers = cfg["block_size"], cfg["layers"]
    kvh, hd = cfg["kv_heads"], cfg["head_dim"]
    t = cfg["prefill_len"]
    tokens_prefill = rng.integers(0, cfg["vocab"], size=(1, t), dtype=np.int32)
    token = rng.integers(0, cfg["vocab"], size=(b,), dtype=np.int32)
    pos = np.full((b,), t, dtype=np.int32)
    pool = (rng.standard_normal((nb, bs, layers, 2, kvh, hd)) * 0.05).astype(np.float32)
    block_tables = np.stack(
        [rng.permutation(nb)[:mb].astype(np.int32) for _ in range(b)]
    )
    gather_pool = (rng.standard_normal((nb, 256)) * 0.1).astype(np.float32)
    gather_idx = rng.permutation(nb)[:mb].astype(np.int32)
    return {
        "tokens_prefill": tokens_prefill,
        "token": token,
        "pos": pos,
        "pool": pool,
        "block_tables": block_tables,
        "gather_pool": gather_pool,
        "gather_idx": gather_idx,
    }


def checksum(x):
    """Stable output fingerprint: shape, abs-sum, first 8 values."""
    a = np.asarray(x, dtype=np.float64).ravel()
    return {
        "shape": list(np.asarray(x).shape),
        "abs_sum": float(np.abs(a).sum()),
        "first8": [float(v) for v in a[:8]],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = CONFIG
    params = init_params(cfg)
    ex = example_inputs(cfg)
    print(f"model: {num_params(cfg)/1e6:.1f}M params, {len(params)} tensors")

    # ---- decode_step ----
    def decode_fn(*args_):
        n = len(params)
        p, (token, pos, pool, bt) = list(args_[:n]), args_[n:]
        return decode_step(p, token, pos, pool, bt, cfg)

    dargs = [jnp.asarray(p) for p in params] + [
        jnp.asarray(ex["token"]),
        jnp.asarray(ex["pos"]),
        jnp.asarray(ex["pool"]),
        jnp.asarray(ex["block_tables"]),
    ]
    lowered = jax.jit(decode_fn).lower(*dargs)
    with open(os.path.join(args.out_dir, "decode_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    dlogits, dnewkv = jax.jit(decode_fn)(*dargs)
    print("decode_step lowered; logits", dlogits.shape)

    # ---- prefill ----
    def prefill_fn(*args_):
        n = len(params)
        p, (tokens,) = list(args_[:n]), args_[n:]
        return prefill(p, tokens, cfg)

    pargs = [jnp.asarray(p) for p in params] + [jnp.asarray(ex["tokens_prefill"])]
    lowered_p = jax.jit(prefill_fn).lower(*pargs)
    with open(os.path.join(args.out_dir, "prefill.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_p))
    plogits, pkv = jax.jit(prefill_fn)(*pargs)
    print("prefill lowered; logits", plogits.shape)

    # ---- kv_gather ----
    gargs = [jnp.asarray(ex["gather_pool"]), jnp.asarray(ex["gather_idx"])]
    lowered_g = jax.jit(kv_gather).lower(*gargs)
    with open(os.path.join(args.out_dir, "kv_gather.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_g))
    gout = jax.jit(kv_gather)(*gargs)
    print("kv_gather lowered; out", gout.shape)

    # ---- meta + goldens ----
    manifest = [
        {"name": n, "shape": list(s), "scale": sc, "offset": off}
        for n, s, sc, off in param_manifest(cfg)
    ]
    meta = {
        "config": cfg,
        "param_manifest": manifest,
        "artifacts": {
            "decode_step": {
                "file": "decode_step.hlo.txt",
                "extra_args": ["token[B]i32", "pos[B]i32",
                               "pool[NB,BS,L,2,KVH,D]f32", "block_tables[B,MB]i32"],
                "outputs": ["logits[B,V]f32", "new_kv[B,L,2,KVH,D]f32"],
            },
            "prefill": {
                "file": "prefill.hlo.txt",
                "extra_args": ["tokens[1,T]i32"],
                "outputs": ["logits[1,V]f32", "kv[T,L,2,KVH,D]f32"],
            },
            "kv_gather": {
                "file": "kv_gather.hlo.txt",
                "args": ["pool[NB,256]f32", "idx[MB]i32"],
                "outputs": ["gathered[MB,256]f32"],
            },
        },
    }
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)

    golden = {
        "input_seed": 7,
        "decode_step": {"logits": checksum(dlogits), "new_kv": checksum(dnewkv)},
        "prefill": {"logits": checksum(plogits), "kv": checksum(pkv)},
        "kv_gather": {"out": checksum(gout)},
        # Spot-check values for cross-language param generation.
        "param_probe": {
            "embed_first4": [float(v) for v in np.asarray(params[0]).ravel()[:4]],
            "unembed_first4": [float(v) for v in np.asarray(params[-1]).ravel()[:4]],
        },
    }
    with open(os.path.join(args.out_dir, "golden.json"), "w") as f:
        json.dump(golden, f, indent=1)
    print("wrote meta.json + golden.json")


if __name__ == "__main__":
    main()
