"""L2: tiny decoder-only transformer with a paged KV cache.

The "small real model" served end-to-end by the Rust coordinator: ~55M
parameters (vocab 16384, d_model 640, 10 layers, GQA 10q/2kv heads, RoPE,
RMSNorm, SwiGLU-less MLP). The decode step calls the L1 Pallas
`paged_attention` kernel, so the kernel lowers into the same HLO artifact
the Rust runtime executes.

Parameters are generated counter-based (splitmix64 → uniform), so the Rust
side regenerates bit-identical weights from the same seed instead of
shipping a multi-hundred-MB params file (see `rust/src/runtime/params.rs`).
"""

import numpy as np
import jax
import jax.numpy as jnp

from .kernels.paged_attention import paged_attention

# ---------------------------------------------------------------- config

CONFIG = {
    "vocab": 16384,
    "d_model": 640,
    "layers": 10,
    "heads": 10,
    "kv_heads": 2,
    "head_dim": 64,
    "ffn": 1920,
    "block_size": 16,        # tokens per KV block (vLLM default)
    "max_blocks": 32,        # blocks per sequence (512-token context)
    "num_blocks": 128,       # pool capacity
    "batch": 4,              # decode batch baked into the artifact
    "prefill_len": 128,      # prefill length baked into the artifact
    "param_seed": 42,
}


# ------------------------------------------------- deterministic weights

def _splitmix64(x):
    """Vectorized splitmix64 over uint64 numpy arrays."""
    mask = np.uint64(0xFFFFFFFFFFFFFFFF)
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & mask
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & mask
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & mask
    return z ^ (z >> np.uint64(31))


def counter_uniform(seed, offset, n):
    """n floats in [-1, 1), from counters seed+offset+i (cross-language)."""
    idx = np.arange(offset, offset + n, dtype=np.uint64) + np.uint64(seed)
    bits = _splitmix64(idx)
    u = (bits >> np.uint64(11)).astype(np.float64) * (1.0 / (1 << 53))
    return (u * 2.0 - 1.0).astype(np.float32)


def param_manifest(cfg=CONFIG):
    """Ordered (name, shape, scale, counter_offset) for every parameter.

    The order here IS the positional argument order of the AOT artifacts;
    `meta.json` carries it to the Rust runtime.
    """
    v, d, layers = cfg["vocab"], cfg["d_model"], cfg["layers"]
    h, kvh, hd, ffn = cfg["heads"], cfg["kv_heads"], cfg["head_dim"], cfg["ffn"]
    entries = []
    offset = 0

    def add(name, shape, scale):
        nonlocal offset
        n = int(np.prod(shape))
        entries.append((name, tuple(shape), float(scale), offset))
        offset += n

    add("embed", (v, d), 0.02)
    for l in range(layers):
        add(f"l{l:02d}.ln1", (d,), 0.0)  # scale 0 → init to ones (see below)
        add(f"l{l:02d}.wq", (d, h * hd), d ** -0.5)
        add(f"l{l:02d}.wk", (d, kvh * hd), d ** -0.5)
        add(f"l{l:02d}.wv", (d, kvh * hd), d ** -0.5)
        add(f"l{l:02d}.wo", (h * hd, d), (h * hd) ** -0.5)
        add(f"l{l:02d}.ln2", (d,), 0.0)
        add(f"l{l:02d}.w1", (d, ffn), d ** -0.5)
        add(f"l{l:02d}.w2", (ffn, d), ffn ** -0.5)
    add("ln_f", (d,), 0.0)
    add("unembed", (d, v), d ** -0.5)
    return entries


def init_params(cfg=CONFIG):
    """Generate the parameter list per the manifest (norm weights = 1)."""
    seed = cfg["param_seed"]
    params = []
    for name, shape, scale, offset in param_manifest(cfg):
        n = int(np.prod(shape))
        if scale == 0.0:
            arr = np.ones(n, dtype=np.float32)
        else:
            arr = counter_uniform(seed, offset, n) * np.float32(scale)
        params.append(jnp.asarray(arr.reshape(shape)))
    return params


# ----------------------------------------------------------- model math

def rmsnorm(x, w):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * w


def rope(x, pos):
    """Rotary embedding. x: [..., H, D]; pos: broadcastable to x[..., 0, 0]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (np.log(10000.0) / half))
    angles = pos[..., None, None] * freqs  # [..., 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _layer_params(params, l, cfg):
    base = 1 + l * 8  # embed first, 8 tensors per layer
    (ln1, wq, wk, wv, wo, ln2, w1, w2) = params[base : base + 8]
    return ln1, wq, wk, wv, wo, ln2, w1, w2


def prefill(params, tokens, cfg=CONFIG):
    """Prefill one sequence.

    Args:
      params: list per `param_manifest`.
      tokens: [1, T] int32.

    Returns:
      (logits_last [1, vocab], kv [T, L, 2, KVH, D]) — RoPE-rotated keys,
      ready to be paged into the pool.
    """
    d, layers = cfg["d_model"], cfg["layers"]
    h, kvh, hd = cfg["heads"], cfg["kv_heads"], cfg["head_dim"]
    embed, unembed, ln_f = params[0], params[-1], params[-2]
    t = tokens.shape[1]
    pos = jnp.arange(t, dtype=jnp.float32)

    x = embed[tokens[0]]  # [T, d]
    kvs = []
    for l in range(layers):
        ln1, wq, wk, wv, wo, ln2, w1, w2 = _layer_params(params, l, cfg)
        xn = rmsnorm(x, ln1)
        q = rope((xn @ wq).reshape(t, h, hd), pos)
        k = rope((xn @ wk).reshape(t, kvh, hd), pos)
        v = (xn @ wv).reshape(t, kvh, hd)
        groups = h // kvh
        kk = jnp.repeat(k, groups, axis=1)
        vv = jnp.repeat(v, groups, axis=1)
        scores = jnp.einsum("qhd,khd->hqk", q, kk) / jnp.sqrt(jnp.float32(hd))
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask[None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("hqk,khd->qhd", p, vv).reshape(t, h * hd)
        x = x + attn @ wo
        xn2 = rmsnorm(x, ln2)
        x = x + jax.nn.gelu(xn2 @ w1) @ w2
        kvs.append(jnp.stack([k, v], axis=1))  # [T, 2, KVH, D]
    logits = rmsnorm(x[-1:], ln_f) @ unembed  # [1, vocab]
    kv = jnp.stack(kvs, axis=1)  # [T, L, 2, KVH, D]
    return logits, kv


def decode_step(params, token, pos, pool, block_tables, cfg=CONFIG):
    """One decode step for a batch, attending over the paged pool via the
    L1 Pallas kernel.

    Args:
      token:        [B] int32 current tokens.
      pos:          [B] int32 context lengths (position of the new token).
      pool:         [NB, BS, L, 2, KVH, D] paged KV pool, all layers
                    contiguous per block (the paper's optimized layout).
      block_tables: [B, MB] int32.

    Returns:
      (logits [B, vocab], new_kv [B, L, 2, KVH, D]) — the caller (Rust
      coordinator) writes new_kv into the pool at pos.
    """
    d, layers = cfg["d_model"], cfg["layers"]
    h, kvh, hd = cfg["heads"], cfg["kv_heads"], cfg["head_dim"]
    b = token.shape[0]
    embed, unembed, ln_f = params[0], params[-1], params[-2]
    fpos = pos.astype(jnp.float32)

    x = embed[token]  # [B, d]
    new_kvs = []
    for l in range(layers):
        ln1, wq, wk, wv, wo, ln2, w1, w2 = _layer_params(params, l, cfg)
        xn = rmsnorm(x, ln1)
        q = rope((xn @ wq).reshape(b, h, hd), fpos)
        k_new = rope((xn @ wk).reshape(b, kvh, hd), fpos)
        v_new = (xn @ wv).reshape(b, kvh, hd)
        layer_pool = pool[:, :, l]  # [NB, BS, 2, KVH, D]
        attn = paged_attention(q, layer_pool, block_tables, pos, k_new, v_new)
        x = x + attn.reshape(b, h * hd) @ wo
        xn2 = rmsnorm(x, ln2)
        x = x + jax.nn.gelu(xn2 @ w1) @ w2
        new_kvs.append(jnp.stack([k_new, v_new], axis=1))  # [B, 2, KVH, D]
    logits = rmsnorm(x, ln_f) @ unembed
    new_kv = jnp.stack(new_kvs, axis=1)  # [B, L, 2, KVH, D]
    return logits, new_kv


def num_params(cfg=CONFIG):
    """Total parameter count."""
    return sum(int(np.prod(s)) for _, s, _, _ in param_manifest(cfg))
