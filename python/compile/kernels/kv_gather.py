"""L1 Pallas kernel: KV block gather — the kernel-based KV fetch analogue.

The paper's third comparator (§5.3.1) fetches dispersed KV blocks with a
single GPU kernel, one workgroup per block. The Pallas expression of the
same schedule: grid over destination blocks; program i copies pool block
`indices[i]` to contiguous output row i. On a real TPU each program is one
HBM→VMEM→HBM round trip of one block; under interpret=True it runs as
numpy and is validated against `ref.ref_kv_gather`.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_kernel(pool_ref, idx_ref, o_ref):
    """Program i: o[i] = pool[idx[i]] (whole-block copy)."""
    idx = idx_ref[0]
    o_ref[...] = jnp.take(pool_ref[...], idx, axis=0)


def kv_gather(pool, indices):
    """Gather KV blocks into a contiguous buffer.

    Args:
      pool:    [NB, E] float32 — flattened blocks (E = block bytes / 4).
      indices: [K] int32 — physical block ids to fetch, in order.

    Returns:
      [K, E] contiguous blocks.
    """
    k = indices.shape[0]
    e = pool.shape[1]
    return pl.pallas_call(
        _gather_kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec(pool.shape, lambda i: (0, 0)),
            pl.BlockSpec((None, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((None, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, e), pool.dtype),
        interpret=True,
    )(pool, indices.reshape(k, 1))
