"""L1 Pallas kernel: decode attention over a paged KV pool (GQA).

This is the compute hot-spot of the serving workload (paper §5.3): one
query token per sequence attends over a context whose KV lives in
dispersed 16-token blocks addressed by a block table — exactly the
PagedAttention layout whose CPU↔GPU movement the paper optimizes.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA version of
this kernel maps one warp per KV block with shared-memory staging; on
TPU-style Pallas we instead grid over the batch, stage the sequence's
blocks HBM→VMEM via the block table, and contract on the MXU with fp32
accumulation. `interpret=True` everywhere — the CPU PJRT client cannot run
Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _paged_attention_kernel(q_ref, pool_ref, bt_ref, len_ref, knew_ref, vnew_ref, o_ref):
    """One program instance = one sequence (grid over batch).

    Block shapes (VMEM view per program):
      q_ref    [H, D]           — current token's queries
      pool_ref [NB, BS, 2, KVH, D] — the layer's whole pool (small model;
                                  a production TPU kernel would stream
                                  per-block via scalar-prefetched BlockSpecs)
      bt_ref   [MB]             — this sequence's block table
      len_ref  [1]              — cached context length
      knew/vnew [KVH, D]        — current token's K/V
      o_ref    [H, D]           — output
    """
    q = q_ref[...]
    pool = pool_ref[...]
    bt = bt_ref[...]
    ctx = len_ref[0]
    k_new = knew_ref[...]
    v_new = vnew_ref[...]

    H, D = q.shape
    KVH = k_new.shape[0]
    groups = H // KVH
    mb = bt.shape[0]
    bs = pool.shape[1]

    kv = jnp.take(pool, bt, axis=0)                  # [MB, BS, 2, KVH, D]
    k = kv[:, :, 0].reshape(mb * bs, KVH, D)
    v = kv[:, :, 1].reshape(mb * bs, KVH, D)
    k = jnp.concatenate([k, k_new[None]], axis=0)    # [T+1, KVH, D]
    v = jnp.concatenate([v, v_new[None]], axis=0)

    # GQA: repeat KV heads across query-head groups.
    k = jnp.repeat(k, groups, axis=1)                # [T+1, H, D]
    v = jnp.repeat(v, groups, axis=1)

    # MXU contraction in fp32.
    scores = jnp.einsum("hd,thd->ht", q, k) / jnp.sqrt(jnp.float32(D))
    t = jnp.arange(k.shape[0])
    mask = (t < ctx) | (t == k.shape[0] - 1)
    scores = jnp.where(mask[None, :], scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=1, keepdims=True))
    p = p / p.sum(axis=1, keepdims=True)
    o_ref[...] = jnp.einsum("ht,thd->hd", p, v)


@functools.partial(jax.jit, static_argnames=())
def paged_attention(q, pool, block_tables, ctx_lens, k_new, v_new):
    """Batched paged decode attention.

    Args:
      q:            [B, H, D]
      pool:         [NB, BS, 2, KVH, D] (one layer's pool)
      block_tables: [B, MB] int32
      ctx_lens:     [B] int32
      k_new, v_new: [B, KVH, D]

    Returns:
      [B, H, D]
    """
    B, H, D = q.shape
    grid = (B,)
    return pl.pallas_call(
        _paged_attention_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, H, D), lambda b: (b, 0, 0)),
            # Whole pool visible to each program; index_map pins block 0.
            pl.BlockSpec(pool.shape, lambda b: (0,) * pool.ndim),
            pl.BlockSpec((None, block_tables.shape[1]), lambda b: (b, 0)),
            pl.BlockSpec((None, 1), lambda b: (b, 0)),
            pl.BlockSpec((None, k_new.shape[1], D), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, v_new.shape[1], D), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, H, D), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=True,
    )(q, pool, block_tables, ctx_lens.reshape(B, 1), k_new, v_new)


def vmem_footprint_bytes(pool_shape, h, d, mb):
    """Estimated per-program VMEM footprint (DESIGN.md §Perf, L1): the
    quantities a real-TPU variant must tile under the ~16 MiB VMEM budget."""
    nb, bs, two, kvh, dd = pool_shape
    gathered = mb * bs * two * kvh * dd * 4
    q_out = 2 * h * d * 4
    return gathered + q_out
