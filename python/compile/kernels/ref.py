"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package must match its `ref_*` counterpart to float32
tolerance; `python/tests/test_kernels.py` sweeps shapes with hypothesis.
"""

import jax.numpy as jnp


def ref_paged_attention(q, pool, block_table, ctx_len, k_new, v_new):
    """Decode-step attention for ONE sequence over a paged KV pool.

    Args:
      q:          [H, D] query for the current token.
      pool:       [NB, BS, 2, KVH, D] paged KV pool for one layer
                  (dim 2: 0=key, 1=value).
      block_table:[MB] int32 physical block ids for this sequence.
      ctx_len:    scalar int32, tokens already cached (ctx_len <= MB*BS).
      k_new:      [KVH, D] current token's key.
      v_new:      [KVH, D] current token's value.

    Returns:
      [H, D] attention output over the cached context plus current token.
    """
    H, D = q.shape
    KVH = k_new.shape[0]
    groups = H // KVH
    mb = block_table.shape[0]
    bs = pool.shape[1]

    kv = pool[block_table]                     # [MB, BS, 2, KVH, D]
    k = kv[:, :, 0].reshape(mb * bs, KVH, D)   # [T, KVH, D]
    v = kv[:, :, 1].reshape(mb * bs, KVH, D)
    k = jnp.concatenate([k, k_new[None]], axis=0)   # [T+1, KVH, D]
    v = jnp.concatenate([v, v_new[None]], axis=0)

    # Expand KV heads to query heads (GQA).
    k = jnp.repeat(k, groups, axis=1)          # [T+1, H, D]
    v = jnp.repeat(v, groups, axis=1)

    scores = jnp.einsum("hd,thd->ht", q, k) / jnp.sqrt(jnp.float32(D))
    t = jnp.arange(k.shape[0])
    mask = (t < ctx_len) | (t == k.shape[0] - 1)   # cached ∪ current
    scores = jnp.where(mask[None, :], scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=1, keepdims=True))
    p = p / p.sum(axis=1, keepdims=True)
    return jnp.einsum("ht,thd->hd", p, v)


def ref_kv_gather(pool, indices):
    """Gather whole KV blocks: pool [NB, E] by indices [K] -> [K, E]."""
    return pool[indices]


def ref_causal_attention(q, k, v):
    """Plain causal attention, [T, H, D] each (prefill oracle)."""
    T, H, D = q.shape
    scores = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(jnp.float32(D))
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    scores = jnp.where(mask[None], scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", p, v)
